"""Fault domains (DESIGN.md §10): session isolation, tool-call
resilience, KV-pressure degradation, deadlines/disconnects, and the
deterministic chaos harness.

The load-bearing claim: any single-session fault degrades exactly one
session.  Every isolation assertion therefore checks both sides — the
faulted session reaches a terminal state (no consumer awaits forever)
AND the unfaulted sessions' streams stay token-identical to the greedy
oracle, with the pool's slots/pages fully reclaimed afterwards."""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest
from _serving_util import events_by_session, oracle_streams

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import (ChaosRun, FaultPlan, FaultSpec,
                                  drive_chaos)
from repro.serving.gateway import AgentGateway, GatewayConfig, Rejected
from repro.serving.kvcache import KVExhausted, PagedKVCachePool
from repro.serving.metrics import OpenLoopReport, build_open_loop_report
from repro.serving.policies import POLICIES
from repro.serving.request import SessionState
from repro.serving.workload import make_open_loop_workload

TINY = ModelConfig(name="tiny-faults", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")
TINY_PAGED = dataclasses.replace(TINY, name="tiny-faults-paged",
                                 kv_layout="paged", kv_page_size=64)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, *, cfg=TINY, num_slots=4, kv_defer_limit=8):
    ecfg = EngineConfig(num_slots=num_slots, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        max_wall_s=float("inf"),
                        kv_defer_limit=kv_defer_limit)
    return ServingEngine(cfg, params, POLICIES["agentserve"], ecfg)


def _sessions(n, *, seed=0, rate=8.0):
    return make_open_loop_workload(n, workload="react",
                                   vocab_size=TINY.vocab_size,
                                   token_scale=0.0625, seed=seed,
                                   rate_rps=rate)


# ---------------------------------------------------------------------------
# chaos acceptance: mixed faults, one run, both sides of the isolation
# claim
# ---------------------------------------------------------------------------

def test_chaos_mixed_faults_isolated_and_reclaimed(tiny_params):
    """One seeded chaos run over the paged engine mixing every fault
    kind: a recoverable tool error (retry succeeds), a hanging tool
    (timeouts exhaust -> abort policy), an engine step fault
    (quarantine), a client disconnect, and a page-exhaustion burst
    (transparent deferral).  Unfaulted sessions must stream
    token-identically to the fault-free oracle; faulted sessions must
    reach a terminal state; the pool must reclaim every slot and leak
    no pages."""
    eng = _engine(tiny_params, cfg=TINY_PAGED)
    plan = FaultPlan((
        FaultSpec(kind="tool_error", session_id=1, attempts=1),  # recovers
        FaultSpec(kind="tool_hang", session_id=2),               # aborts
        FaultSpec(kind="step_error", session_id=3, at_count=2),
        FaultSpec(kind="disconnect", session_id=4, at_token=3),
        FaultSpec(kind="page_exhaustion", at_count=10, count=2),
    ), seed=7)
    gw = AgentGateway(eng, GatewayConfig(
        high_watermark=32, tool_timeout_s=0.5, tool_retries=1,
        tool_backoff_base_s=0.01, tool_failure_policy="abort"),
        faults=plan)
    sessions = _sessions(6)
    arrivals = [0.05 * i for i in range(6)]

    async def go():
        await gw.start()
        run = await asyncio.wait_for(
            drive_chaos(gw, sessions, arrivals, plan), timeout=120.0)
        await gw.stop(timeout_s=60.0)
        return run

    run: ChaosRun = asyncio.run(go())
    # submissions happened in arrival order, so plan sids == list index
    assert [s.session_id for s in sessions] == list(range(6))

    # every stream reached a terminal state — nothing wedged
    assert run.wedged() == 0
    assert not run.rejected
    assert {s.session_id for s in run.aborted} == {2, 3, 4}
    assert {s.session_id for s in run.completed} == {0, 1, 5}

    # the unfaulted (and retry-recovered) sessions are token-identical
    # to the fault-free greedy reference
    streams = run.streams()
    want = oracle_streams(TINY_PAGED, tiny_params, sessions,
                          num_slots=4, max_seq=512)
    for sid in (0, 1, 5):
        assert streams[sid] == want[sid], f"session {sid} diverged"
    # a quarantined session's partial stream is a prefix of the oracle
    got3 = streams.get(3, [])
    assert got3 == want[3][:len(got3)]

    # abort attribution
    reasons = {s.session_id: s.abort_reason for s in run.aborted}
    assert reasons[2] == "tool_failed"
    assert reasons[3] == "injected_step_error"
    assert reasons[4] == "disconnected"
    assert all(s.state == SessionState.ABORTED for s in run.aborted)
    assert len(run.recovery_s) == 1 and run.recovery_s[0] < 60.0

    # fault accounting
    assert gw.counters["aborted"] == 3
    assert gw.counters["cancelled"] == 1
    assert gw.counters["tool_retries"] >= 1      # session 1 recovered
    assert gw.counters["tool_timeouts"] >= 2     # session 2 hung twice
    assert plan.injected["step_error"] == 1
    assert plan.injected["page_exhaustion"] >= 1
    assert eng.hotpath_stats["kv_deferred"] >= 1
    assert eng.hotpath_stats["aborted"] == 3
    stats = gw.stats()
    assert stats["aborted"] == 3.0 and stats["kv_deferred"] >= 1.0

    # resource reclamation: every slot free, no page held outside the
    # prefix cache, allocated count consistent with the refcounts
    pool = eng.pool
    assert pool.free_slots == eng.ecfg.num_slots
    prefix_refs = sum(len(e.pages) for e in pool._prefix.values())
    assert int(pool.refcount.sum()) == prefix_refs
    assert pool.num_pages - pool.free_pages == int(
        np.count_nonzero(pool.refcount))


# ---------------------------------------------------------------------------
# tool-call resilience
# ---------------------------------------------------------------------------

def test_tool_retry_recovers_token_exact(tiny_params):
    """A tool that fails once per call recovers on retry: the session
    completes token-exactly, with retries counted and zero errors."""
    eng = _engine(tiny_params)
    calls = {}

    async def flaky(sess, turn_idx):
        k = (sess.session_id, turn_idx)
        calls[k] = calls.get(k, 0) + 1
        if calls[k] == 1:
            raise RuntimeError("flaky")
        return None

    gw = AgentGateway(eng, GatewayConfig(
        high_watermark=32, tool_retries=2, tool_backoff_base_s=0.01),
        tool_fn=flaky)
    sessions = _sessions(1, seed=5)

    async def go():
        await gw.start()
        run = await drive_chaos(gw, sessions, [0.0], FaultPlan())
        await gw.stop(timeout_s=60.0)
        return run

    run = asyncio.run(go())
    assert len(run.completed) == 1 and not run.aborted
    n_tools = len(sessions[0].turns) - 1
    assert gw.counters["tool_retries"] == n_tools
    assert gw.counters["tool_errors"] == 0
    streams = run.streams()
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=4, max_seq=512)
    assert streams[sessions[0].session_id] == want[sessions[0].session_id]


def test_tool_timeout_abort_policy_reclaims_slot(tiny_params):
    """tool_failure_policy='abort': a tool that hangs past the timeout
    on every attempt aborts the session — terminal error event, slot
    reclaimed, timeouts counted."""
    eng = _engine(tiny_params)

    async def hang(sess, turn_idx):
        await asyncio.sleep(60.0)
        return None

    gw = AgentGateway(eng, GatewayConfig(
        high_watermark=32, tool_timeout_s=0.1, tool_retries=1,
        tool_backoff_base_s=0.01, tool_failure_policy="abort"),
        tool_fn=hang)
    sessions = _sessions(1, seed=3)

    async def go():
        await gw.start()
        run = await asyncio.wait_for(
            drive_chaos(gw, sessions, [0.0], FaultPlan()), timeout=60.0)
        await gw.stop(timeout_s=60.0)
        return run

    run = asyncio.run(go())
    assert not run.completed and len(run.aborted) == 1
    s = run.aborted[0]
    assert s.state == SessionState.ABORTED
    assert s.abort_reason == "tool_failed"
    assert gw.counters["tool_timeouts"] == 2      # 1 attempt + 1 retry
    assert gw.counters["tool_errors"] == 1        # once per exhausted call
    assert eng.pool.free_slots == eng.ecfg.num_slots
    # the terminal error event reached the client stream
    last = run.events[-1][1]
    assert last.error and last.abort_reason == "tool_failed"


def test_bad_tool_failure_policy_rejected(tiny_params):
    with pytest.raises(ValueError):
        AgentGateway(_engine(tiny_params),
                     GatewayConfig(tool_failure_policy="explode"))


# ---------------------------------------------------------------------------
# deadlines & disconnects
# ---------------------------------------------------------------------------

def test_deadline_abort_is_planner_visible(tiny_params):
    """A submit-time SLO deadline in the past aborts the session on the
    next cycle (reason='deadline'); a generous deadline completes."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    doomed, fine = _sessions(2, seed=8)

    async def go():
        await gw.start()
        res_d = await gw.submit(doomed, deadline_s=0.0)
        res_f = await gw.submit(fine, deadline_s=600.0)
        evs_d = [ev async for ev in res_d.events()]
        evs_f = [ev async for ev in res_f.events()]
        await gw.stop(timeout_s=60.0)
        return evs_d, evs_f

    evs_d, evs_f = asyncio.run(go())
    assert evs_d and evs_d[-1].error
    assert evs_d[-1].abort_reason == "deadline"
    assert doomed.state == SessionState.ABORTED
    assert fine.state == SessionState.FINISHED
    assert not any(ev.error for ev in evs_f)
    assert eng.hotpath_stats["deadline_aborts"] == 1
    assert gw.stats()["deadline_aborts"] == 1.0
    assert eng.pool.free_slots == eng.ecfg.num_slots


def test_cancel_mid_stream_reclaims_promptly(tiny_params):
    """LiveSession.cancel() (client disconnect) terminates the stream
    with an error event and frees the slot while other sessions keep
    streaming token-exactly."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    sessions = _sessions(2, seed=4)
    plan = FaultPlan((FaultSpec(kind="disconnect", session_id=0,
                                at_token=2),))

    async def go():
        await gw.start()
        run = await asyncio.wait_for(
            drive_chaos(gw, sessions, [0.0, 0.05], plan), timeout=60.0)
        await gw.stop(timeout_s=60.0)
        return run

    run = asyncio.run(go())
    assert {s.session_id for s in run.aborted} == {0}
    assert run.aborted[0].abort_reason == "disconnected"
    assert gw.counters["cancelled"] == 1
    assert len(run.completed) == 1
    survivor = run.completed[0]
    streams = run.streams()
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=4, max_seq=512)
    assert streams[survivor.session_id] == want[survivor.session_id]
    assert eng.pool.free_slots == eng.ecfg.num_slots


# ---------------------------------------------------------------------------
# admission under pressure
# ---------------------------------------------------------------------------

def test_watermark_queue_timeout_sheds(tiny_params):
    """Queue-mode admission: a waiter that never sees the gate reopen is
    shed with a 429-style Rejected after queue_timeout_s."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(
        high_watermark=1, low_watermark=0, admission="queue",
        queue_timeout_s=0.05))
    first, second = _sessions(2, seed=10)

    async def go():
        # gateway deliberately NOT started: the staged submit op keeps
        # occupancy pinned >= 1, so the gate can never reopen
        res1 = await gw.submit(first)
        res2 = await gw.submit(second)
        return res1, res2

    res1, res2 = asyncio.run(go())
    assert not isinstance(res1, Rejected)
    assert isinstance(res2, Rejected)
    assert res2.status == 429
    assert gw.counters["rejected"] == 1


def test_kv_pressure_tightens_gate(tiny_params):
    """A recent KVExhausted deferral tightens the effective admission
    watermark; the pressure clears once the window passes."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=8,
                                         kv_pressure_tighten=6))
    assert gw.gate.effective_high() == 8
    eng.hotpath_stats["kv_deferred"] += 1
    eng._kv_last_defer_cycle = eng._cycle     # deferral "this cycle"
    gw._kv_pressure_gate()
    assert gw.gate.pressure == 6
    assert gw.gate.effective_high() == max(gw.gate.low + 1, 2)
    eng._cycle += 1000                        # window long past
    gw._kv_pressure_gate()
    assert gw.gate.pressure == 0 and gw.gate.effective_high() == 8


# ---------------------------------------------------------------------------
# stop() drain timeout: consumers never hang
# ---------------------------------------------------------------------------

def test_stop_timeout_fails_live_streams(tiny_params):
    """A drain timeout (e.g. a tool that never returns, with a timeout
    too large to trip) pushes terminal error events so every events()
    consumer unblocks."""
    eng = _engine(tiny_params)

    async def never(sess, turn_idx):
        await asyncio.sleep(3600.0)
        return None

    gw = AgentGateway(eng, GatewayConfig(high_watermark=32,
                                         tool_timeout_s=3600.0),
                      tool_fn=never)
    sessions = _sessions(1, seed=2)

    async def go():
        await gw.start()
        res = await gw.submit(sessions[0])
        consumer = asyncio.ensure_future(
            _collect(res))
        # wait until the session is parked in TOOL_WAIT
        for _ in range(2000):
            if gw.counters["tool_calls"] >= 1:
                break
            await asyncio.sleep(0.01)
        assert gw.counters["tool_calls"] >= 1
        await gw.stop(timeout_s=0.3)
        return await asyncio.wait_for(consumer, timeout=10.0)

    async def _collect(res):
        return [ev async for ev in res.events()]

    evs = asyncio.run(go())
    assert evs and evs[-1].error
    assert evs[-1].abort_reason == "gateway_stopped"
    assert gw.counters["aborted"] == 1


# ---------------------------------------------------------------------------
# paged pool: prepare_append rollback on mid-call exhaustion
# ---------------------------------------------------------------------------

def _paged_pool(num_pages, num_slots=4, max_seq=64):
    cfg = dataclasses.replace(TINY, name="tiny-rollback",
                              kv_layout="paged", kv_page_size=8)
    return PagedKVCachePool(cfg, num_slots, max_seq, num_pages=num_pages)


def test_prepare_append_rollback_plain_alloc():
    """Exhaustion mid-append (plain allocations) must unwind the pages
    the same call already claimed: table row, refcounts and free count
    exactly as before."""
    pool = _paged_pool(num_pages=4)
    slot = pool.alloc()
    pool.prepare_append(slot, 0, 3 * 8)      # 3 pages
    assert pool.free_pages == 1
    table_before = pool.block_table.copy()
    ref_before = pool.refcount.copy()
    with pytest.raises(KVExhausted):
        pool.prepare_append(slot, 3 * 8, 3 * 8)   # needs 3, has 1
    assert pool.free_pages == 1
    np.testing.assert_array_equal(pool.block_table, table_before)
    np.testing.assert_array_equal(pool.refcount, ref_before)
    pool.free(slot)
    assert pool.free_pages == 4


def test_prepare_append_rollback_cow():
    """Exhaustion mid-COW must re-increment the shared source page and
    restore the table mapping — the sharing session keeps its data and
    nothing leaks."""
    pool = _paged_pool(num_pages=3)
    s0 = pool.alloc()
    pool.prepare_append(s0, 0, 16)           # 2 pages
    pool.lengths[s0] = 16
    tokens = np.arange(16, dtype=np.int32)
    pool.register_prefix(s0, tokens)         # refs: slot0 + prefix
    s1 = pool.alloc()
    entry = pool.lookup(tokens)
    pool.restore_prefix(s1, entry)           # refs: + slot1 == 3 each
    assert pool.free_pages == 1
    shared = pool.block_table[s1, :2].copy()
    ref_before = pool.refcount.copy()
    # both pages are shared -> COW both; only one free page exists, so
    # the second copy hits KVExhausted and the first must roll back
    with pytest.raises(KVExhausted):
        pool.prepare_append(s1, 0, 16)
    assert pool.free_pages == 1
    np.testing.assert_array_equal(pool.block_table[s1, :2], shared)
    np.testing.assert_array_equal(pool.refcount, ref_before)
    pool.free(s1)
    pool.free(s0)
    assert int(pool.refcount.sum()) == sum(
        len(e.pages) for e in pool._prefix.values())


def test_pool_fault_hook_injects_exhaustion():
    """The chaos plan's pool_hook fails exactly the planned allocation
    indices — and alloc state is untouched by an injected failure."""
    pool = _paged_pool(num_pages=8)
    plan = FaultPlan((FaultSpec(kind="page_exhaustion", at_count=1,
                                count=2),))
    pool.fault_hook = plan.pool_hook
    slot = pool.alloc()
    pool.prepare_append(slot, 0, 8)          # alloc #0: fine
    with pytest.raises(KVExhausted):
        pool.prepare_append(slot, 8, 8)      # alloc #1: injected
    with pytest.raises(KVExhausted):
        pool.prepare_append(slot, 8, 8)      # alloc #2: injected
    pool.prepare_append(slot, 8, 8)          # alloc #3: past the burst
    assert plan.injected["page_exhaustion"] == 2
    assert pool.free_pages == 8 - 2


# ---------------------------------------------------------------------------
# fault plan determinism + reporting
# ---------------------------------------------------------------------------

def test_fault_plan_generate_deterministic():
    kw = dict(tool_error_rate=0.2, tool_hang_rate=0.1,
              step_error_rate=0.1, disconnect_rate=0.1,
              page_fault_bursts=2)
    a = FaultPlan.generate(11, 40, **kw)
    b = FaultPlan.generate(11, 40, **kw)
    assert a.specs == b.specs
    assert a.specs != FaultPlan.generate(12, 40, **kw).specs
    # at most one fault per session
    sids = [sp.session_id for sp in a.specs if sp.session_id >= 0]
    assert len(sids) == len(set(sids))


def test_open_loop_report_counts_aborts(tiny_params):
    """The abort column rides the CSV row (header parity) and the
    per-reason histogram attributes every aborted session."""
    sessions = _sessions(3, seed=1)
    sessions[1].abort_reason = "deadline"
    sessions[2].abort_reason = "disconnected"
    rep = build_open_loop_report("agentserve", sessions[:1], 1.0, 2.0,
                                 rejected=1,
                                 aborted_sessions=sessions[1:])
    assert rep.aborted == 2
    assert rep.submitted == 1 + 1 + 2
    assert rep.abort_reasons == {"deadline": 1, "disconnected": 1}
    assert len(rep.row().split(",")) == len(OpenLoopReport.HEADER.split(","))
