"""Per-architecture smoke tests (contract deliverable f): a REDUCED
variant of each assigned architecture's family (<=2 layers / one hybrid
group, d_model<=512, <=4 experts) runs one forward and one train step on
CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ASSIGNED_ARCHS, get_config, get_smoke_config)
from repro.models import (forward_decode, forward_prefill, forward_train,
                          init_cache, init_params)
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    if cfg.frontend != "none":
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model)),
                "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    assert cfg.moe is None or cfg.moe.num_experts <= 4
    params = init_params(cfg, KEY)
    B, S = 2, 16
    batch = _inputs(cfg, B, S)
    logits, aux = forward_train(params, cfg, batch.get("tokens"),
                                embeds=batch.get("embeds"), moe_mode="dense")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, opt_cfg, moe_mode="dense", remat=True)
    opt = init_opt_state(opt_cfg, params)
    batch = _inputs(cfg)
    params2, opt2, stats = step(params, opt, batch)
    assert np.isfinite(float(stats["loss"]))
    assert not np.isnan(np.asarray(
        jax.tree_util.tree_leaves(params2)[0])).any()
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).has_decode_phase])
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, 32)
    lg, cache, lens = forward_prefill(
        params, cfg, toks, cache, jnp.zeros((B,), jnp.int32),
        moe_mode="dense")
    assert lg.shape == (B, cfg.vocab_size)
    lg2, cache, lens = forward_decode(params, cfg, jnp.argmax(lg, -1),
                                      cache, lens, moe_mode="dense")
    assert lg2.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(lg2)).any()
    assert int(lens[0]) == S + 1


def test_encoder_only_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert not cfg.has_decode_phase
    assert not cfg.supports_shape("decode_32k")
    assert not cfg.supports_shape("long_500k")
    assert cfg.supports_shape("prefill_32k")


def test_long_context_windows():
    # dense archs get the sanctioned SWA variant at long_500k only
    dense = get_config("llama3.2-3b")
    assert dense.attention_window_for("long_500k") == 8192
    assert dense.attention_window_for("decode_32k") == 0
    # mixtral is natively SWA everywhere
    assert get_config("mixtral-8x22b").attention_window_for("decode_32k") \
        == 4096
    # SSM/hybrid need no window
    assert get_config("mamba2-780m").attention_window_for("long_500k") == 0
    assert get_config("jamba-1.5-large-398b").attention_window_for(
        "long_500k") == 0
