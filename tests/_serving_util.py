"""Shared serving-test helper: a turn-by-turn reference decode oracle.

Greedy decoding is scheduling-independent — whatever order the engine
interleaves prefill chunks and decode steps across sessions, each
session's token stream must equal the stream produced by running that
session *alone*: whole-prompt prefill, then one greedy decode step per
token.  The oracle computes exactly that with the engine's own warmed
executables, so regression tests can assert token-for-token identity
for any engine/reactor/gateway drive path.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import get_executables
from repro.serving.kvcache import KVCachePool


def oracle_streams(cfg, params, sessions, *, num_slots, max_seq,
                   moe_mode="dense"):
    """{session_id: [token ids]} for each session decoded in isolation.

    Always runs the slab layout — the oracle is the layout-independent
    greedy reference, so paged-engine streams are asserted against the
    exact same executables the slab engine dispatches."""
    if cfg.kv_layout != "slab":
        cfg = dataclasses.replace(cfg, kv_layout="slab")
    ex = get_executables(cfg, num_slots, max_seq, moe_mode)
    out = {}
    for s in sessions:
        pool = KVCachePool(cfg, num_slots, max_seq)
        stream = []
        length = 0
        for turn in s.turns:
            pt = np.asarray(turn.prefill_tokens, np.int32)
            logits, pool.cache = ex.prefill(
                params, pool.cache, jnp.asarray(pt[None]),
                jnp.int32(0), jnp.int32(length), jnp.int32(len(pt) - 1))
            length += len(pt)
            tok = int(np.asarray(logits).argmax())
            stream.append(tok)
            for _ in range(turn.decode_len - 1):
                tvec = np.zeros((num_slots,), np.int32)
                lvec = np.zeros((num_slots,), np.int32)
                tvec[0], lvec[0] = tok, length
                logits2, pool.cache = ex.decode(
                    params, pool.cache, jnp.asarray(tvec),
                    jnp.asarray(lvec))
                length += 1
                tok = int(np.asarray(logits2)[0].argmax())
                stream.append(tok)
        out[s.session_id] = stream
    return out


def events_by_session(events):
    """Group a TokenEvent list into {session_id: [token ids]} preserving
    emission order."""
    out = {}
    for ev in events:
        out.setdefault(ev.session_id, []).append(ev.token)
    return out
