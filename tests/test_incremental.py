"""Incremental serving consistency: prefill + decode must reproduce the
parallel forward exactly, for every architecture family (the property
the whole serving engine rests on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import (forward_decode, forward_prefill, forward_train,
                          init_cache, init_params)

KEY = jax.random.PRNGKey(1)

FAMILIES = ["llama3.2-3b", "mamba2-780m", "jamba-1.5-large-398b",
            "olmoe-1b-7b", "mixtral-8x22b", "qwen2-vl-7b", "starcoder2-15b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_incremental_matches_parallel(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = forward_train(params, cfg, toks, moe_mode="dense")
    cache = init_cache(cfg, B, 32)
    lg, cache, lens = forward_prefill(
        params, cfg, toks[:, :7], cache, jnp.zeros((B,), jnp.int32),
        moe_mode="dense")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 6]),
                               rtol=3e-4, atol=3e-4)
    for t in range(7, S):
        lg, cache, lens = forward_decode(params, cfg, toks[:, t], cache,
                                         lens, moe_mode="dense")
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=4e-4, atol=4e-4)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "jamba-1.5-large-398b"])
def test_resume_prefill_matches(arch):
    """Cold chunk + resume chunk == one long prefill (the cache-extension
    path that makes resume prefills cheap)."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = forward_train(params, cfg, toks, moe_mode="dense")
    cache = init_cache(cfg, B, 32)
    zero = jnp.zeros((B,), jnp.int32)
    _, cache, lens = forward_prefill(params, cfg, toks[:, :7], cache, zero,
                                     moe_mode="dense")
    lg, cache, lens = forward_prefill(params, cfg, toks[:, 7:], cache, lens,
                                      moe_mode="dense")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=4e-4, atol=4e-4)


def test_per_batch_offsets_differ():
    """Sessions at different cache lengths decode correctly in one batch
    (continuous batching): session 0 has 4 cached tokens, session 1 has 7."""
    cfg = get_smoke_config("llama3.2-3b")
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 10), 0, cfg.vocab_size)
    full, _ = forward_train(params, cfg, toks, moe_mode="dense")
    cache = init_cache(cfg, 2, 32)
    zero = jnp.zeros((2,), jnp.int32)
    _, cache, _ = forward_prefill(params, cfg, toks[:, :4], cache, zero,
                                  moe_mode="dense")
    # session 1: pad-extended prefill of 3 more tokens at offset 4
    # (session 0 lane is masked by pointing its write at a scratch area
    # and restoring — here we simply re-write the same tokens, which is
    # idempotent for the KV cache)
    _, cache, _ = forward_prefill(
        params, cfg, toks[:, 4:7], cache,
        jnp.asarray([4, 4], jnp.int32), moe_mode="dense")
    lens = jnp.asarray([7, 7], jnp.int32)
    lg, _, _ = forward_decode(params, cfg, toks[:, 7], cache, lens,
                              moe_mode="dense")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 7]),
                               rtol=4e-4, atol=4e-4)
