"""Competitive-ratio analysis (§III-B): Theorem 1 / Corollary 2 bounds
validated against brute-force offline optima over random monotone
profiles (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="wholly property-based module; pip install -r requirements-dev.txt")
import hypothesis.strategies as st              # noqa: E402
from hypothesis import given, settings          # noqa: E402

from repro.core import competitive as comp


def _profile(rng_list_d, rng_list_c, rng_list_r):
    levels = np.arange(10, 101, 10)
    return comp.ThroughputProfile(
        levels=levels,
        mu_decode=np.cumsum(np.abs(rng_list_d)) + 1.0,
        mu_cold=np.cumsum(np.abs(rng_list_c)) + 1.0,
        mu_resume=np.cumsum(np.abs(rng_list_r)) + 1.0)


floats10 = st.lists(st.floats(0.01, 100.0), min_size=10, max_size=10)


@given(d=floats10, c=floats10, r=floats10)
@settings(max_examples=50)
def test_monotone_projection(d, c, r):
    p = _profile(d, c, r)
    for curve in (p.mu_decode, p.mu_cold, p.mu_resume):
        assert (np.diff(curve) >= 0).all()          # Assumption 1 enforced


@given(d=floats10, c=floats10, r=floats10,
       slo_frac=st.floats(0.05, 0.99))
@settings(max_examples=50)
def test_r_star_minimality(d, c, r, slo_frac):
    p = _profile(d, c, r)
    r_min = slo_frac * p.mu_decode[-1]               # always feasible (Eq. 5)
    rg = comp.r_star_g(p, r_min)
    assert p.mu_d(rg) >= r_min                       # meets the SLO
    below = p.levels[p.levels < rg]
    for lv in below:                                  # minimal (Lemma 1)
        assert p.mu_decode[list(p.levels).index(lv)] < r_min


def test_infeasible_slo_raises():
    p = _profile([1] * 10, [1] * 10, [1] * 10)
    with pytest.raises(ValueError):
        comp.r_star_g(p, r_min=1e9)


@given(d=floats10, c=floats10, r=floats10,
       eta=st.floats(0, 1), delta=st.floats(0, 30),
       eps=st.floats(0, 0.5), slo_frac=st.floats(0.05, 0.95))
@settings(max_examples=80)
def test_theorem1_bound_holds(d, c, r, eta, delta, eps, slo_frac):
    """An SLO-feasible controller that allocates R*_g + delta (quantised)
    must retain at least the Theorem-1 fraction of the offline optimum."""
    p = _profile(d, c, r)
    slo_ms = 1000.0 / (slo_frac * p.mu_decode[-1])
    rg = comp.r_star_g(p, comp.r_min_from_slo(slo_ms))
    bound = comp.instantaneous_bound(p, eta=eta, tpot_slo_ms=slo_ms,
                                     delta=delta, eps_bar=eps)
    assert 0.0 <= bound <= 1.0
    # simulate the worst allowed controller: R_A = min(R*_g + delta, S)
    S = p.levels[-1]
    r_alloc = min(rg + delta, S)
    etas = [eta] * 8
    achieved = comp.achieved_service(p, etas, [r_alloc] * 8, [eps] * 8)
    optimum = comp.offline_optimum(p, etas, slo_ms)
    assert achieved >= bound * optimum - 1e-6


@given(d=floats10, c=floats10, r=floats10, eta=st.floats(0, 1),
       delta=st.floats(0, 30), eps=st.floats(0, 0.5))
@settings(max_examples=50)
def test_corollary2_not_tighter_than_theorem1(d, c, r, eta, delta, eps):
    """The linearised bound must never exceed... it may be looser or equal
    but both must be valid lower bounds <= 1; we check ordering against
    the achieved ratio implicitly via Theorem 1's test; here: sanity."""
    p = _profile(d, c, r)
    slo_ms = 1000.0 / (0.5 * p.mu_decode[-1])
    b1 = comp.instantaneous_bound(p, eta=eta, tpot_slo_ms=slo_ms,
                                  delta=delta, eps_bar=eps)
    b2 = comp.linearized_bound(p, eta=eta, tpot_slo_ms=slo_ms,
                               delta=delta, eps_bar=eps)
    assert 0.0 <= b2 <= 1.0 and 0.0 <= b1 <= 1.0
    # Cor. 2 uses the max slope over the interval, hence is the looser one
    assert b2 <= b1 + 1e-9
