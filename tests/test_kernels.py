"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
K1, K2, K3, K4 = jax.random.split(KEY, 4)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,Sq,H,Hk,hd,win", [
    (2, 64, 4, 2, 32, 0),
    (1, 100, 8, 8, 64, 0),       # MHA, non-multiple seq
    (2, 128, 6, 2, 32, 48),      # GQA + sliding window
    (1, 37, 4, 1, 16, 0),        # MQA, odd seq
    (3, 96, 4, 4, 128, 32),      # TPU-width head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, H, Hk, hd, win, dtype):
    q = jax.random.normal(K1, (B, Sq, H, hd), dtype)
    k = jax.random.normal(K2, (B, Sq, Hk, hd), dtype)
    v = jax.random.normal(K3, (B, Sq, Hk, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=win,
                              block_q=32, block_k=32)
    exp = ref.naive_attention(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    q = jax.random.normal(K1, (2, 50, 4, 16))
    k = jax.random.normal(K2, (2, 50, 4, 16))
    v = jax.random.normal(K3, (2, 50, 4, 16))
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    exp = ref.naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,S,H,Hk,hd", [
    (2, 64, 4, 2, 32),
    (3, 100, 8, 4, 16),
    (1, 256, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, S, H, Hk, hd, dtype):
    q = jax.random.normal(K1, (B, 1, H, hd), dtype)
    kc = jax.random.normal(K2, (B, S, Hk, hd), dtype)
    vc = jax.random.normal(K3, (B, S, Hk, hd), dtype)
    lens = jax.random.randint(K4, (B,), 1, S + 1)
    out = ops.flash_decode(q, kc, vc, lens, block_k=32)
    exp = ref.naive_decode_attention(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,nh,hd,N,chunk", [
    (2, 64, 4, 16, 8, 16),
    (1, 50, 2, 32, 16, 16),      # non-multiple seq -> padding
    (2, 128, 3, 64, 32, 32),
])
def test_ssd_scan(B, S, nh, hd, N, chunk):
    x = jax.random.normal(K1, (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(K2, (B, S, nh)))
    A = -jnp.exp(jax.random.normal(K3, (nh,)))
    Bm = jax.random.normal(K4, (B, S, N))
    Cm = jax.random.normal(K1, (B, S, N))
    h0 = jnp.zeros((B, nh, hd, N))
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, h0, chunk=chunk)
    yr, hr = ref.naive_ssd(x, dt, Bm, Cm, A, jnp.zeros((nh,)), h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_state_carry():
    """Splitting a sequence across two calls must equal one call."""
    B, S, nh, hd, N = 1, 64, 2, 16, 8
    x = jax.random.normal(K1, (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(K2, (B, S, nh)))
    A = -jnp.exp(jax.random.normal(K3, (nh,)))
    Bm = jax.random.normal(K4, (B, S, N))
    Cm = jax.random.normal(K1, (B, S, N))
    h0 = jnp.zeros((B, nh, hd, N))
    y_full, h_full = ops.ssd_scan(x, dt, A, Bm, Cm, h0, chunk=16)
    y1, h1 = ops.ssd_scan(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                          h0, chunk=16)
    y2, h2 = ops.ssd_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                          h1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("E,C,d,f", [
    (4, 32, 64, 96),
    (2, 50, 48, 40),     # non-multiple dims -> padding
    (8, 16, 128, 256),   # MXU-width contraction
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, d, f, dtype):
    x = jax.random.normal(K1, (E, C, d), dtype)
    w = jax.random.normal(K2, (E, d, f), dtype)
    out = ops.moe_gmm(x, w, block_c=16, block_f=32, block_d=32)
    exp = ref.naive_gmm(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))
