"""End-to-end system behaviour: AgentServe's headline properties on a
real (tiny) model — the paper's qualitative claims, scaled to CPU.

These are the system-level acceptance tests; the quantitative
reproduction lives in benchmarks/ (Fig 2/3/5/6/7, Table I)."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import collect_tpots
from repro.serving.policies import POLICIES
from repro.serving.workload import make_workload

TINY = ModelConfig(name="tiny-sys", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")


@pytest.fixture(scope="module")
def env():
    params = init_params(TINY, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=6, max_seq=640, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        tpot_slo_ms=15.0, max_wall_s=90.0)
    return params, ecfg


def _run(params, ecfg, policy, seed=7, n=4):
    sessions = make_workload(n, workload="react", vocab_size=TINY.vocab_size,
                             token_scale=0.125, num_system_prompts=1,
                             seed=seed, stagger_s=0.05)
    eng = ServingEngine(TINY, params, POLICIES[policy], ecfg)
    rep = eng.run(sessions)
    return rep, eng, sessions


def test_agentserve_beats_fcfs_on_tpot_tail(env):
    """The paper's core claim, directionally: phase-aware scheduling
    beats head-of-line-blocking FCFS on TPOT tail latency."""
    params, ecfg = env
    rep_as, _, _ = _run(params, ecfg, "agentserve")
    rep_fc, _, _ = _run(params, ecfg, "fcfs")
    assert rep_as.tpot_p95_s < rep_fc.tpot_p95_s
    assert rep_as.ttft_p50_s < rep_fc.ttft_p50_s


def test_prefix_cache_hits_across_sessions(env):
    params, ecfg = env
    rep, eng, _ = _run(params, ecfg, "agentserve", n=5)
    assert rep.extra["prefix_hits"] >= 1


def test_controller_reacts_to_load(env):
    """Algorithm 1 must actually move its control variables during a
    contended run (not sit at the initial point)."""
    params, ecfg = env
    _, eng, _ = _run(params, ecfg, "agentserve", n=5)
    r_values = {t["r_min"] for t in eng.trace}
    b_values = {t["b_prefill"] for t in eng.trace}
    assert len(r_values) > 1 or len(b_values) > 1


def test_rebind_cheap_vs_warmup(env):
    """Green-Context analogue economics: pre-establishing slots is orders
    of magnitude more expensive than rebinding between them (paper:
    context construction >> <50us rebinds)."""
    params, ecfg = env
    _, eng, _ = _run(params, ecfg, "agentserve")
    warm_total = sum(eng.slots.stats.warmup_s.values())
    if eng.slots.stats.rebinds:
        assert eng.slots.stats.mean_rebind_us * 1e-6 < warm_total
