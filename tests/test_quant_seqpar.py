"""Beyond-paper serving optimizations (§Perf): int8 KV cache and
sequence-parallel flash decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.kernels import ref
from repro.models import (forward_decode, forward_prefill, forward_train,
                          init_cache, init_params)
from repro.models.attention import quantize_kv

KEY = jax.random.PRNGKey(3)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (2, 16, 4, 32))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
    # max error per element is bounded by scale/2 = max|row| / 254
    bound = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / 254 + 1e-6
    assert (err <= bound + 1e-5).all()


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x22b"])
def test_int8_cache_decode_close_to_exact(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = forward_train(params, cfg, toks, moe_mode="dense")
    cache = init_cache(cfg, B, 32, kv_quant=True)
    lg, cache, lens = forward_prefill(
        params, cfg, toks[:, :7], cache, jnp.zeros((B,), jnp.int32),
        moe_mode="dense")
    for t in range(7, S):
        lg, cache, lens = forward_decode(params, cfg, toks[:, t], cache,
                                         lens, moe_mode="dense")
    err = float(jnp.max(jnp.abs(lg - full[:, -1])))
    assert err < 0.1, err          # quantization noise, not divergence
    # and it is NOT bit-exact (the cache really is quantised)
    cache_leaf = jax.tree_util.tree_leaves(cache)[0]


def test_int8_cache_halves_bytes():
    cfg = get_smoke_config("llama3.2-3b")
    full = init_cache(cfg, 2, 64)
    quant = init_cache(cfg, 2, 64, kv_quant=True)
    b = lambda c: sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(c))
    ratio = b(quant) / b(full)
    assert ratio < 0.6, ratio      # int8 + 1/hd scale overhead


def _make_mesh_compat():
    """``axis_types`` only exists on newer jax; the pinned 0.4.x
    toolchain defaults to the same (Auto) behaviour without it."""
    at = getattr(jax.sharding, "AxisType", None)
    kw = {"axis_types": (at.Auto,) * 2} if at is not None else {}
    return jax.make_mesh((4, 2), ("data", "model"), **kw)


def _seqpar_env():
    from repro.distributed.context import SPMDContext
    mesh = _make_mesh_compat()
    return SPMDContext(mesh=mesh, dp_axes=("data",), tp_axis="model")


@pytest.mark.skipif(jax.device_count() != 1, reason="uses host-device trick")
def test_seqpar_decode_matches_naive():
    import os
    import subprocess
    import sys
    # needs >1 device: run in a subprocess with forced host devices
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import decode_attention_seqpar, quantize_kv
from repro.kernels import ref
from repro.distributed.context import SPMDContext
at = getattr(jax.sharding, "AxisType", None)
kw = {"axis_types": (at.Auto,)*2} if at is not None else {}
mesh = jax.make_mesh((4, 2), ("data", "model"), **kw)
spmd = SPMDContext(mesh=mesh, dp_axes=("data",), tp_axis="model")
B, S, H, Hk, hd = 2, 64, 4, 2, 16
ks_ = jax.random.split(jax.random.PRNGKey(0), 5)
q = jax.random.normal(ks_[0], (B, 1, H, hd))
kc = jax.random.normal(ks_[1], (B, S, Hk, hd))
vc = jax.random.normal(ks_[2], (B, S, Hk, hd))
kn = jax.random.normal(ks_[3], (B, 1, Hk, hd))
vn = jax.random.normal(ks_[4], (B, 1, Hk, hd))
lens = jnp.asarray([40, 63], jnp.int32)
for win in (0, 24):
    out, ck, cv = decode_attention_seqpar(q, kn, vn, kc, vc, lens + 1,
                                          spmd, window=win)
    kc_ref = kc.at[jnp.arange(B), lens].set(kn[:, 0])
    vc_ref = vc.at[jnp.arange(B), lens].set(vn[:, 0])
    exp = ref.naive_decode_attention(q, kc_ref, vc_ref, lens + 1,
                                     window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "OK" in r.stdout, r.stderr[-2000:]
