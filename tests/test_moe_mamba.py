"""MoE and Mamba-2 layer-level tests: path equivalence, capacity
semantics, router properties, SSD chunk/step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import MoEConfig, SSMConfig
from repro.models import mamba2
from repro.models.moe import apply_moe, init_moe
from repro.kernels import ref

KEY = jax.random.PRNGKey(2)


def _moe_parts(E=4, k=2, d=32, f=64):
    moe = MoEConfig(num_experts=E, top_k=k)
    params = init_moe(KEY, d, f, moe, "swiglu", jnp.float32)
    x = jax.random.normal(KEY, (2, 8, d))
    return moe, params, x


def test_gmm_matches_dense_without_drops():
    moe, params, x = _moe_parts()
    out_d, aux_d = apply_moe(params, x, moe, "swiglu", mode="dense")
    out_g, aux_g = apply_moe(params, x, moe, "swiglu", mode="gmm",
                             capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-6)


def test_gmm_sharded_dispatch_matches():
    moe, params, x = _moe_parts()
    out1, _ = apply_moe(params, x, moe, "swiglu", mode="gmm",
                        capacity_factor=8.0, data_shards=1)
    out2, _ = apply_moe(params, x, moe, "swiglu", mode="gmm",
                        capacity_factor=8.0, data_shards=4)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-5, atol=2e-5)


def test_capacity_drops_reduce_output():
    """With capacity ~0 most tokens are dropped -> output ~ 0."""
    moe, params, x = _moe_parts()
    out, _ = apply_moe(params, x, moe, "swiglu", mode="gmm",
                       capacity_factor=0.01)
    full, _ = apply_moe(params, x, moe, "swiglu", mode="dense")
    assert np.abs(np.asarray(out)).sum() < np.abs(np.asarray(full)).sum()


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_aux_loss_near_balanced_floor(seed):
    """Switch aux loss E*sum(f*P) ~ 1 near balance.  The exact >=1 bound
    (Cauchy-Schwarz) holds when f == P; with top-k dispatch f and P can
    decorrelate slightly, so we assert the floor with top-k slack and
    that imbalance is penalised upward, never rewarded toward 0."""
    moe, params, _ = _moe_parts()
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, 32))
    _, aux = apply_moe(params, x, moe, "swiglu", mode="dense")
    assert 0.9 <= float(aux) < float(moe.num_experts) + 1e-3


# ---------------------------------------------------------------------------
# Mamba-2 / SSD
# ---------------------------------------------------------------------------

def _ssm_parts(d=32):
    ssm = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                    chunk_size=8)
    params = mamba2.init_mamba2(KEY, d, ssm, jnp.float32)
    return ssm, params


def test_scan_matches_stepwise_decode():
    """Chunked SSD scan over a sequence == token-by-token recurrence."""
    d = 32
    ssm, params = _ssm_parts(d)
    B, S = 2, 24
    u = jax.random.normal(KEY, (B, S, d)) * 0.3
    st0 = mamba2.init_ssm_state(B, d, ssm, jnp.float32)
    y_scan, st_scan = mamba2.apply_mamba2_scan(params, u, st0, ssm)
    st = st0
    ys = []
    for t in range(S):
        y_t, st = mamba2.apply_mamba2_step(params, u[:, t:t + 1], st, ssm)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_scan.ssd), np.asarray(st.ssd),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_scan.conv_x),
                               np.asarray(st.conv_x), rtol=1e-5, atol=1e-5)


def test_scan_state_carry_across_calls():
    d = 32
    ssm, params = _ssm_parts(d)
    B, S = 1, 32
    u = jax.random.normal(KEY, (B, S, d)) * 0.3
    st0 = mamba2.init_ssm_state(B, d, ssm, jnp.float32)
    y_full, st_full = mamba2.apply_mamba2_scan(params, u, st0, ssm)
    y1, st1 = mamba2.apply_mamba2_scan(params, u[:, :20], st0, ssm)
    y2, st2 = mamba2.apply_mamba2_scan(params, u[:, 20:], st1, ssm)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st2.ssd), np.asarray(st_full.ssd),
                               rtol=3e-4, atol=3e-4)
