"""Unified telemetry layer (DESIGN.md §11): metrics registry, span
lifecycle, Prometheus text format, Chrome trace export, and the
span-based latency reconstruction cross-check.

The two load-bearing invariants:

  * every terminal session state — DONE, ABORTED (tool failure, step
    fault, disconnect, deadline, kv_exhausted) — closes all of the
    session's spans and its slot span, so ``open_span_count() == 0``
    after any drained run;
  * the engine's stats surface is ONE registry: ``engine.stats()``,
    ``gateway.stats()`` and the Prometheus rendering are views of the
    same object, so their key sets cannot drift.
"""
import asyncio
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec, drive_chaos
from repro.serving.gateway import (AgentGateway, GatewayConfig,
                                   drive_open_loop)
from repro.serving.metrics import collect_tpots, collect_ttfts
from repro.serving.policies import POLICIES
from repro.serving.telemetry import (Counter, Gauge, Histogram,
                                     MetricsRegistry, RegistryDict,
                                     SpanTracer, Telemetry, _main,
                                     export_trace, parse_prometheus_text,
                                     reconstruct_latency,
                                     validate_trace_events)
from repro.serving.workload import make_open_loop_workload

TINY = ModelConfig(name="tiny-telemetry", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")
TINY_PAGED = dataclasses.replace(TINY, name="tiny-telemetry-paged",
                                 kv_layout="paged", kv_page_size=64)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, *, cfg=TINY, num_slots=4, **over):
    ecfg = EngineConfig(num_slots=num_slots, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        max_wall_s=float("inf"), **over)
    return ServingEngine(cfg, params, POLICIES["agentserve"], ecfg)


def _sessions(n, *, seed=0, rate=8.0):
    return make_open_loop_workload(n, workload="react",
                                   vocab_size=TINY.vocab_size,
                                   token_scale=0.0625, seed=seed,
                                   rate_rps=rate)


def _drive(gateway, sessions, *, stop_timeout=60.0):
    arrivals = [s.ready_s for s in sessions]

    async def go():
        await gateway.start()
        run = await drive_open_loop(gateway, sessions, arrivals)
        await gateway.stop(timeout_s=stop_timeout)
        return run

    return asyncio.run(go())


def _terminal_markers(tracer):
    """sid -> (terminal phase, abort reason) from the span ring."""
    out = {}
    for track, sid, name, _t0, _t1, args in tracer.spans:
        if track == "session" and name in ("DONE", "ABORTED"):
            out[sid] = (name, (args or {}).get("reason"))
    return out


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("requests", help="total requests")
    assert reg.counter("requests") is c         # get-or-create
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = reg.gauge("depth")
    g.set(7.0)
    assert g.read() == 7.0
    reg.gauge("depth", fn=lambda: 9.0)          # re-register binds the fn
    assert g.read() == 9.0
    with pytest.raises(ValueError):
        reg.gauge("requests")                   # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("bad name with spaces")
    assert [m.name for m in reg.metrics()] == ["requests", "depth"]


def test_registry_snapshot_is_flat_and_nan_free():
    reg = MetricsRegistry()
    reg.counter("hits").inc(2)
    reg.gauge("occ", fn=lambda: 0.5)
    h = reg.histogram("lat_s")
    snap = reg.snapshot()                       # histogram still empty
    assert snap["hits"] == 2.0 and snap["occ"] == 0.5
    assert snap["lat_s_count"] == 0.0 and snap["lat_s_p95"] == 0.0
    h.observe(0.01)
    h.observe(0.02, count=3)                    # weighted flush-style call
    snap = reg.snapshot()
    assert snap["lat_s_count"] == 4.0
    assert snap["lat_s_sum"] == pytest.approx(0.01 + 3 * 0.02)
    assert all(isinstance(v, float) and not math.isnan(v)
               for v in snap.values())


def test_histogram_percentiles_from_samples():
    h = Histogram("t")
    for v in np.linspace(0.001, 0.1, 100):
        h.observe(float(v))
    assert h.percentile(50) == pytest.approx(0.05, rel=0.05)
    assert h.percentile(99) == pytest.approx(0.1, rel=0.05)
    assert h.total == 100


def test_registry_dict_keeps_dict_syntax_and_rename():
    reg = MetricsRegistry()
    d = RegistryDict(reg, {"steps": 0, "aborted": 0},
                     rename={"aborted": "engine_aborted"})
    d["steps"] += 5                             # legacy call-site syntax
    d["aborted"] += 1
    assert d["steps"] == 5 and dict(d) == {"steps": 5, "aborted": 1}
    snap = reg.snapshot()                       # renamed in the registry,
    assert snap["steps"] == 5.0                 # plain at the call site
    assert snap["engine_aborted"] == 1.0 and "aborted" not in snap
    with pytest.raises(KeyError):
        d["unknown"] += 1                       # keys fixed at construction
    with pytest.raises(TypeError):
        del d["steps"]


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def test_prometheus_text_round_trip():
    reg = MetricsRegistry()
    reg.counter("reqs", help="requests served").inc(3)
    reg.gauge("q", fn=lambda: 2.0)
    h = reg.histogram("lat_s", buckets=(0.01, 0.1, 1.0))
    h.observe(0.005)
    h.observe(0.05, count=2)
    h.observe(5.0)                              # above every finite bucket
    text = reg.prometheus_text()
    assert "# TYPE reqs counter" in text
    assert "# HELP reqs requests served" in text
    samples = parse_prometheus_text(text)
    assert samples["reqs"] == 3.0 and samples["q"] == 2.0
    assert samples['lat_s_bucket{le="0.01"}'] == 1.0      # cumulative
    assert samples['lat_s_bucket{le="0.1"}'] == 3.0
    assert samples['lat_s_bucket{le="+Inf"}'] == 4.0
    assert samples["lat_s_count"] == 4.0
    assert samples["lat_s_sum"] == pytest.approx(0.005 + 0.1 + 5.0)


@pytest.mark.parametrize("bad", [
    "# TYPE x wibble\nx 1\n",                   # unknown type
    "no_type_header 1\n",                       # sample precedes TYPE
    "# TYPE x counter\nx notanumber\n",         # bad value
    "# WAT x counter\n",                        # malformed comment
    '# TYPE h histogram\nh_bucket{le="1"} 5\nh_bucket{le="2"} 3\n',
])
def test_prometheus_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_prometheus_text(bad)


# ---------------------------------------------------------------------------
# span tracer + trace_event export
# ---------------------------------------------------------------------------

def test_span_tracer_lifecycle_and_terminal_markers():
    tr = SpanTracer()
    tr.transition(7, "QUEUED", 0.0)
    tr.slot_bind(0, 7, 0.1)
    tr.transition(7, "PREFILL", 0.1, turn=0)
    tr.transition(7, "DECODE", 0.2, tokens=5)
    tr.child(7, "tool_attempt", 0.3, 0.35, attempt=0, outcome="ok")
    assert tr.open_span_count() == 2            # session + slot
    tr.slot_free(0, 0.4)
    tr.transition(7, "DONE", 0.4)
    assert tr.open_span_count() == 0
    assert _terminal_markers(tr) == {7: ("DONE", None)}
    # terminal marker is zero-length, QUEUED->PREFILL->DECODE all closed
    names = [s[2] for s in tr.spans if s[0] == "session"]
    assert names == ["QUEUED", "PREFILL", "tool_attempt", "DECODE", "DONE"]

    tr2 = SpanTracer(spans_max=4)               # bounded ring
    for i in range(10):
        tr2.cycle(i, "decode", float(i), float(i) + 0.5)
    assert len(tr2.spans) == 4


def test_trace_export_validates_and_keeps_open_spans_loadable():
    tr = SpanTracer()
    tr.transition(0, "QUEUED", 0.0)
    tr.transition(0, "PREFILL", 0.5, turn=0)    # stays open: live dump
    tr.slot_bind(2, 0, 0.5)
    tr.cycle(3, "mega+admit", 0.1, 0.2, planned=64, actual=60)
    doc = export_trace(tr)
    n = validate_trace_events(doc)
    assert n == len(doc["traceEvents"])
    phases = [ev["ph"] for ev in doc["traceEvents"]]
    assert phases.count("B") == 2               # open session + slot span
    cyc = [ev for ev in doc["traceEvents"]
           if ev["ph"] == "X" and ev["pid"] == 3]
    assert cyc[0]["args"]["plan_id"] == 3
    assert doc["displayTimeUnit"] == "ms"


@pytest.mark.parametrize("bad", [
    {"foo": 1},                                  # no traceEvents
    {"traceEvents": []},                         # empty
    {"traceEvents": [{"ph": "Z", "pid": 1, "tid": 1, "name": "x"}]},
    {"traceEvents": [{"ph": "X", "pid": "a", "tid": 1, "name": "x",
                      "ts": 0, "dur": 1}]},      # non-int pid
    {"traceEvents": [{"ph": "X", "pid": 1, "tid": 1, "name": "x",
                      "ts": 0, "dur": -5}]},     # negative dur
    {"traceEvents": [{"ph": "B", "pid": 1, "tid": 1, "name": "x"}]},
])
def test_trace_validation_rejects_malformed(bad):
    with pytest.raises(ValueError):
        validate_trace_events(bad)


# ---------------------------------------------------------------------------
# engine integration: spans close, latency reconstructs, stats unify
# ---------------------------------------------------------------------------

def test_normal_run_spans_close_and_latency_reconstructs(
        tiny_params, tmp_path):
    """A clean multi-agent gateway run: every session timeline reaches
    DONE, zero spans leak, and TTFT/TPOT recovered *from the spans
    alone* match metrics.py within the 1% acceptance bound.  Cycle
    spans correlate with the plan journal by plan id."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    sessions = _sessions(4, rate=8.0)
    run = _drive(gw, sessions)
    assert len(run.completed) == 4

    tr = eng.telemetry.tracer
    assert tr.open_span_count() == 0
    marks = _terminal_markers(tr)
    assert all(marks[s.session_id][0] == "DONE" for s in sessions)
    # tool attempts ride the session track as child spans
    tools = [s for s in tr.spans if s[2] == "tool_attempt"]
    assert len(tools) == sum(len(s.turns) - 1 for s in sessions)
    assert all((s[5] or {}).get("outcome") == "ok" for s in tools)

    # --- the acceptance cross-check: spans vs metrics.py ---------------
    span_ttfts, span_tpot = reconstruct_latency(tr.spans)
    want_ttfts = collect_ttfts(run.completed)
    want_tpots = collect_tpots(run.completed)
    assert len(span_ttfts) == len(want_ttfts)
    assert np.mean(span_ttfts) == pytest.approx(
        np.mean(want_ttfts), rel=0.01)
    assert span_tpot == pytest.approx(float(np.mean(want_tpots)), rel=0.01)

    # --- plan-journal correlation --------------------------------------
    cycle_ids = {(s[5] or {})["plan_id"] for s in tr.spans
                 if s[0] == "cycle"}
    journal_ids = {r.plan.plan_id for r in eng.journal.records}
    assert cycle_ids and cycle_ids <= journal_ids

    # --- hot-path histograms populated ---------------------------------
    snap = eng.stats()
    assert snap["ttft_s_count"] >= len(want_ttfts)
    assert snap["dispatch_gap_s_count"] > 0
    assert snap["device_wait_s_count"] > 0

    # --- the dumped trace validates end to end -------------------------
    path = str(tmp_path / "trace.json")
    assert eng.telemetry.export_trace(path) > 0
    assert _main([path]) == 0


def test_stats_views_are_one_registry(tiny_params, tmp_path):
    """engine.stats(), gateway.stats() and the Prometheus rendering are
    views of one registry — identical key sets by construction, and the
    exposition text parses with every counter/gauge present."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    run = _drive(gw, _sessions(2, rate=16.0))
    assert len(run.completed) == 2

    es, gs = eng.stats(), gw.stats()
    assert set(es) == set(gs)
    assert es == gs                             # same registry, same values
    text = eng.telemetry.registry.prometheus_text()
    samples = parse_prometheus_text(text)
    for m in eng.telemetry.registry.metrics():
        if isinstance(m, (Counter, Gauge)):
            assert m.name in samples, f"{m.name} missing from /metrics"
        else:
            assert f"{m.name}_count" in samples
    # legacy dict facades still read/write through the same registry
    assert gs["fused_steps"] == eng.hotpath_stats["fused_steps"]
    assert gs["completed"] == gw.counters["completed"] == 2
    mpath = tmp_path / "metrics.txt"
    mpath.write_text(text)
    tpath = tmp_path / "trace.json"
    eng.telemetry.export_trace(str(tpath))
    assert _main([str(tpath), str(mpath)]) == 0


def test_faulted_terminals_close_all_spans(tiny_params):
    """Chaos run mixing tool-failure, step-fault, disconnect and an
    injected page-exhaustion burst over the paged engine with
    kv_defer_limit=0 (first deferral -> kv_exhausted abort): every
    terminal path must close its session and slot spans."""
    eng = _engine(tiny_params, cfg=TINY_PAGED, kv_defer_limit=0)
    plan = FaultPlan((
        FaultSpec(kind="tool_hang", session_id=1),
        FaultSpec(kind="step_error", session_id=2, at_count=2),
        FaultSpec(kind="disconnect", session_id=3, at_token=3),
        FaultSpec(kind="page_exhaustion", at_count=6, count=1),
    ), seed=3)
    gw = AgentGateway(eng, GatewayConfig(
        high_watermark=32, tool_timeout_s=0.5, tool_retries=1,
        tool_backoff_base_s=0.01, tool_failure_policy="abort"),
        faults=plan)
    sessions = _sessions(5)
    arrivals = [0.05 * i for i in range(5)]

    async def go():
        await gw.start()
        run = await asyncio.wait_for(
            drive_chaos(gw, sessions, arrivals, plan), timeout=120.0)
        await gw.stop(timeout_s=60.0)
        return run

    run = asyncio.run(go())
    assert run.wedged() == 0
    tr = eng.telemetry.tracer
    assert tr.open_span_count() == 0, \
        f"leaked spans: {tr.open_spans()}"
    marks = _terminal_markers(tr)
    reasons = {sid: r for sid, (ph, r) in marks.items() if ph == "ABORTED"}
    for s in run.aborted:
        assert reasons.get(s.session_id) == s.abort_reason
    for s in run.completed:
        assert marks[s.session_id][0] == "DONE"
    # the exhaustion burst actually fired and attributed its abort
    assert plan.injected["page_exhaustion"] >= 1
    assert eng.hotpath_stats["kv_deferred"] >= 1
    assert "kv_exhausted" in reasons.values()
    # a faulted run's trace still exports clean
    validate_trace_events(export_trace(tr))


def test_deadline_abort_closes_spans(tiny_params):
    """A submit-time deadline in the past aborts on the next cycle; the
    ABORTED marker carries reason='deadline' and nothing leaks."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    doomed, fine = _sessions(2, seed=8)

    async def go():
        await gw.start()
        res_d = await gw.submit(doomed, deadline_s=0.0)
        res_f = await gw.submit(fine, deadline_s=600.0)
        evs_d = [ev async for ev in res_d.events()]
        evs_f = [ev async for ev in res_f.events()]
        await gw.stop(timeout_s=60.0)
        return evs_d, evs_f

    evs_d, evs_f = asyncio.run(go())
    assert evs_d[-1].abort_reason == "deadline"
    assert not any(ev.error for ev in evs_f)
    tr = eng.telemetry.tracer
    assert tr.open_span_count() == 0
    marks = _terminal_markers(tr)
    assert marks[doomed.session_id] == ("ABORTED", "deadline")
    assert marks[fine.session_id][0] == "DONE"


def test_telemetry_off_still_serves_and_stats(tiny_params):
    """telemetry=False drops the tracer (export is a hard error) but
    the registry — the stats surface — stays fully live."""
    eng = _engine(tiny_params, telemetry=False)
    assert eng.telemetry.tracer is None
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    run = _drive(gw, _sessions(2, rate=16.0))
    assert len(run.completed) == 2
    assert eng.stats()["completed"] == 2.0
    assert eng.stats()["dispatch_gap_s_count"] > 0
    with pytest.raises(RuntimeError):
        eng.telemetry.export_trace("/tmp/nope.json")


def test_telemetry_shared_registry_two_gateways(tiny_params):
    """Two gateways over one engine must not collide in the registry:
    get-or-create returns the same counters and the callback gauges
    rebind to the latest gateway."""
    eng = _engine(tiny_params)
    gw1 = AgentGateway(eng, GatewayConfig(high_watermark=32))
    gw2 = AgentGateway(eng, GatewayConfig(high_watermark=32))
    gw1.counters["completed"] += 1
    assert gw2.counters["completed"] == 1       # same underlying counter
    assert eng.stats()["completed"] == 1.0


def test_run_resets_spans_between_runs(tiny_params):
    """Closed-loop ServingEngine.run() starts a fresh trace per run —
    spans from a previous run never bleed into the next timeline."""
    eng = _engine(tiny_params)

    def cohort(seed):
        ss = make_open_loop_workload(
            2, workload="react", vocab_size=TINY.vocab_size,
            token_scale=0.0625, seed=seed, rate_rps=1000.0)
        for s in ss:
            s.ready_s = 0.0
        return ss

    eng.run(cohort(1))
    tr = eng.telemetry.tracer
    assert tr.open_span_count() == 0
    assert sum(1 for s in tr.spans
               if s[0] == "session" and s[2] == "DONE") == 2

    eng.run(cohort(2))                          # same engine, fresh trace
    assert tr.open_span_count() == 0
    assert sum(1 for s in tr.spans
               if s[0] == "session" and s[2] == "DONE") == 2
