"""Device-resident hot-path parity (DESIGN.md §3) and the cache-aware
prefill path (DESIGN.md §4).

The fused decode step, the K-step megastep and the batched resume
prefill must be *semantically invisible*: identical token streams and
cache state to the seed per-step path (host argmax + where-select
commit + serial batch-1 resume), for both attention and Mamba/hybrid
stacks.  Plus interpret-mode parity for the block-skipping decode and
length-pruned prefill kernels against their pure-JAX references, and an
engine e2e check that the Pallas prefill path is token-stream-identical
to the XLA reference path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels import ops, ref
from repro.models import (POSITIONAL_CACHE_KEYS, forward_decode,
                          forward_decode_fused, forward_decode_megastep,
                          forward_prefill, forward_resume_batch, init_cache,
                          init_params)
from repro.models.attention import (blocked_attention,
                                    blocked_attention_quant, quantize_kv)
from repro.serving.kvcache import KVCachePool

HYBRID = ModelConfig(name="tiny-hybrid-hp", family="hybrid", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=128, tie_embeddings=True,
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                   head_dim=32, chunk_size=32),
                     hybrid_period=2, hybrid_attn_index=0, source="test")

B, S_CACHE, CTX = 4, 64, 12


def _params_for(cfg):
    return init_params(cfg, jax.random.PRNGKey(1))


def _ctx_cache(params, cfg):
    """A cache with CTX real tokens in every slot (batch-B prefill)."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, CTX)).astype(np.int32)
    cache = init_cache(cfg, B, S_CACHE)
    logits, cache, lengths = forward_prefill(
        params, cfg, jnp.asarray(toks), cache, jnp.zeros((B,), jnp.int32),
        moe_mode="dense")
    tokens = np.asarray(jnp.argmax(logits, -1), np.int32)
    return cache, np.asarray(lengths, np.int32), tokens


def _seed_decode(params, cfg, cache, tokens, lengths, mask, steps):
    """The seed engine's per-step path: decode -> host argmax ->
    where-select commit (KVCachePool.commit semantics) -> host lengths."""
    m = jnp.asarray(mask)
    tokens, lengths = tokens.copy(), lengths.copy()
    stream = []
    for _ in range(steps):
        logits, new_cache, _ = forward_decode(
            params, cfg, jnp.asarray(tokens), cache, jnp.asarray(lengths),
            moe_mode="dense")
        logits = np.asarray(logits)

        def sel(new, old):
            shape = (1, new.shape[1]) + (1,) * (new.ndim - 2)
            return jnp.where(m.reshape(shape), new, old)

        cache = jax.tree.map(sel, new_cache, cache)
        for b in range(len(tokens)):
            if mask[b]:
                tokens[b] = logits[b].argmax()
                lengths[b] += 1
        stream.append(tokens.copy())
    return np.stack(stream), cache, lengths


def _fused_decode(params, cfg, cache, tokens, lengths, mask, steps):
    t = jnp.asarray(tokens)
    l = jnp.asarray(lengths)
    a = jnp.asarray(mask)
    stream = []
    for _ in range(steps):
        t, cache, l = forward_decode_fused(params, cfg, t, cache, l, a,
                                           moe_mode="dense")
        stream.append(np.asarray(t, np.int32))
    return np.stack(stream), cache, np.asarray(l, np.int32)


def _assert_cache_close(got, want, *, skip_scratch_row=True):
    """Compare caches leaf-wise.  For positional (attention KV) leaves
    the scratch (last) sequence row is excluded: the fused path parks
    inactive lanes' writes there by design."""
    for name, layer in want.items():
        positional = set(layer) <= POSITIONAL_CACHE_KEYS
        for k in layer:
            g, w = got[name][k], layer[k]
            if positional and skip_scratch_row:
                g, w = g[:, :, :-1], w[:, :, :-1]
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5,
                err_msg=f"{name}/{k}")


@pytest.mark.parametrize("cfg", [None, HYBRID], ids=["dense", "hybrid"])
def test_fused_decode_matches_seed_path(cfg, tiny_cfg):
    cfg = cfg or tiny_cfg
    params = _params_for(cfg)
    cache, lengths, tokens = _ctx_cache(params, cfg)
    mask = np.array([True, False, True, True])
    c2 = jax.tree.map(jnp.copy, cache)
    want_stream, want_cache, want_len = _seed_decode(
        params, cfg, cache, tokens, lengths, mask, steps=6)
    got_stream, got_cache, got_len = _fused_decode(
        params, cfg, c2, tokens, lengths, mask, steps=6)
    # inactive lanes: seed leaves the token unchanged, fused keeps input
    np.testing.assert_array_equal(got_stream[:, mask], want_stream[:, mask])
    np.testing.assert_array_equal(got_stream[:, ~mask],
                                  np.broadcast_to(tokens[~mask],
                                                  got_stream[:, ~mask].shape))
    np.testing.assert_array_equal(got_len, want_len)
    _assert_cache_close(got_cache, want_cache)


@pytest.mark.parametrize("cfg", [None, HYBRID], ids=["dense", "hybrid"])
def test_megastep_matches_repeated_fused(cfg, tiny_cfg):
    cfg = cfg or tiny_cfg
    params = _params_for(cfg)
    cache, lengths, tokens = _ctx_cache(params, cfg)
    mask = np.array([True, True, False, True])
    K = 5
    c2 = jax.tree.map(jnp.copy, cache)
    want_stream, want_cache, want_len = _fused_decode(
        params, cfg, cache, tokens, lengths, mask, steps=K)
    toks_seq, last, got_cache, got_len = forward_decode_megastep(
        params, cfg, jnp.asarray(tokens), c2, jnp.asarray(lengths),
        jnp.asarray(mask), num_steps=K, moe_mode="dense")
    np.testing.assert_array_equal(np.asarray(toks_seq), want_stream)
    np.testing.assert_array_equal(np.asarray(last), want_stream[-1])
    np.testing.assert_array_equal(np.asarray(got_len), want_len)
    _assert_cache_close(got_cache, want_cache)


@pytest.mark.parametrize("cfg", [None, HYBRID], ids=["dense", "hybrid"])
def test_batched_resume_matches_serial(cfg, tiny_cfg):
    cfg = cfg or tiny_cfg
    params = _params_for(cfg)
    cache, lengths, _ = _ctx_cache(params, cfg)
    rng = np.random.default_rng(3)
    slots = [0, 2, 3]
    takes = [5, 9, 16]
    bucket = 16
    rows = np.zeros((len(slots), bucket), np.int32)
    for i, t in enumerate(takes):
        rows[i, :t] = rng.integers(0, cfg.vocab_size, size=t)

    # serial seed path: per-row slice -> batch-1 prefill -> update-slice
    serial_cache = jax.tree.map(jnp.copy, cache)
    serial_logits = []
    for i, slot in enumerate(slots):
        sub = jax.tree.map(
            lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
            serial_cache)
        lg, sub2, _ = forward_prefill(
            params, cfg, jnp.asarray(rows[i][None]), sub,
            jnp.asarray([lengths[slot]], jnp.int32), moe_mode="dense",
            logit_idx=jnp.asarray([takes[i] - 1], jnp.int32))
        serial_cache = jax.tree.map(
            lambda full, s, _slot=slot: jax.lax.dynamic_update_slice_in_dim(
                full, s, _slot, axis=1),
            serial_cache, sub2)
        serial_logits.append(np.asarray(lg[0]))

    logits, got_cache = forward_resume_batch(
        params, cfg, jnp.asarray(rows), cache,
        jnp.asarray(slots, jnp.int32),
        jnp.asarray([lengths[s] for s in slots], jnp.int32),
        jnp.asarray([t - 1 for t in takes], jnp.int32), moe_mode="dense")
    logits = np.asarray(logits)
    np.testing.assert_allclose(logits, np.stack(serial_logits),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(logits.argmax(-1),
                                  np.stack(serial_logits).argmax(-1))
    _assert_cache_close(got_cache, serial_cache, skip_scratch_row=False)


def test_decode_kernel_block_skip_parity():
    """interpret=True parity for the revisit-block index maps: short
    lengths leave most KV tiles out of range (skipped), output must
    still match the naive oracle."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    Bq, S, H, Hk, hd = 3, 256, 4, 2, 32
    q = jax.random.normal(k1, (Bq, 1, H, hd))
    kc = jax.random.normal(k2, (Bq, S, Hk, hd))
    vc = jax.random.normal(k3, (Bq, S, Hk, hd))
    for lens in ([1, 37, 256], [5, 5, 5], [33, 64, 200]):
        lengths = jnp.asarray(lens, jnp.int32)
        out = ops.flash_decode(q, kc, vc, lengths, block_k=32,
                               interpret=True)
        exp = ref.naive_decode_attention(q, kc, vc, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# cache-aware prefill kernel (DESIGN.md §4)
# ---------------------------------------------------------------------------

PREFILL_CASES = {
    # (H, Hk, Sq, window, q_offset, lengths) against a 256-row cache:
    # lengths = q_offset + Sq (the serving invariant: the chunk itself
    # is counted), exercising causal pruning, GQA head groups, sliding
    # windows and short-lengths (mostly-empty cache) tile skipping.
    "causal": (4, 4, 32, 0, [0, 16, 96], [32, 48, 128]),
    "gqa": (8, 2, 40, 0, [0, 100, 200], [40, 140, 240]),
    "window": (4, 2, 32, 48, [0, 64, 180], [32, 96, 212]),
    "short_lengths": (4, 2, 16, 0, [0, 0, 8], [16, 16, 24]),
    "unaligned": (4, 2, 23, 0, [5, 77, 131], [28, 100, 154]),
}


def _prefill_case(name):
    H, Hk, Sq, window, qoff, lens = PREFILL_CASES[name]
    S, hd = 256, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(sum(map(ord, name))), 3)
    q = jax.random.normal(k1, (3, Sq, H, hd))
    kc = jax.random.normal(k2, (3, S, Hk, hd))
    vc = jax.random.normal(k3, (3, S, Hk, hd))
    return (q, kc, vc, jnp.asarray(qoff, jnp.int32),
            jnp.asarray(lens, jnp.int32), window)


@pytest.mark.parametrize("case", list(PREFILL_CASES))
def test_prefill_kernel_parity(case):
    """interpret=True parity of the length-pruned Pallas prefill kernel
    vs the pure-JAX blocked_attention reference (acceptance bound:
    max abs diff < 1e-4)."""
    q, kc, vc, qoff, lens, window = _prefill_case(case)
    out = ops.flash_prefill(q, kc, vc, qoff, lens, window=window,
                            block_q=32, block_k=32, interpret=True)
    exp = blocked_attention(q, kc, vc, q_offset=qoff, lengths=lens,
                            causal=True, window=window, block_size=64)
    assert float(jnp.max(jnp.abs(out - exp))) < 1e-4
    # and vs the naive oracle, so reference bugs can't cancel out
    oracle = ref.naive_attention(q, kc, vc, causal=True, window=window,
                                 q_offset=qoff, lengths=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("case", ["causal", "gqa", "window"])
def test_prefill_kernel_quant_parity(case):
    """int8-KV variant: per-tile VMEM dequantisation must match the
    pure-JAX quantised scan under the same pruning."""
    q, kc, vc, qoff, lens, window = _prefill_case(case)
    kq, ks = quantize_kv(kc)
    vq, vs = quantize_kv(vc)
    out = ops.flash_prefill_quant(q, kq, ks, vq, vs, qoff, lens,
                                  window=window, block_q=32, block_k=32,
                                  interpret=True)
    exp = blocked_attention_quant(q, kq, ks, vq, vs, q_offset=qoff,
                                  lengths=lens, causal=True, window=window,
                                  block_size=64)
    assert float(jnp.max(jnp.abs(out - exp))) < 1e-4


def test_engine_prefill_backend_token_parity(tiny_cfg):
    """Engine e2e: identical per-session token outcomes with the Pallas
    prefill path enabled vs disabled (the ModelConfig switch must be
    semantically invisible), and the prefill-side telemetry counts
    tiles on both paths."""
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.policies import POLICIES
    from repro.serving.request import SessionState
    from repro.serving.workload import make_workload

    params = _params_for(tiny_cfg)
    ecfg = EngineConfig(num_slots=4, max_seq=256, cycle_budget=48,
                        granularity=8, b_min=8, b_max=64, b_init=16,
                        delta_b=8, control_interval_s=0.05, max_wall_s=120.0)
    outcomes = {}
    for backend in ("xla", "pallas"):
        cfg = dataclasses.replace(tiny_cfg, name=f"{tiny_cfg.name}-{backend}",
                                  prefill_kernel=backend)
        sessions = make_workload(2, workload="react",
                                 vocab_size=cfg.vocab_size, token_scale=0.04,
                                 num_system_prompts=1, seed=7, stagger_s=0.05)
        eng = ServingEngine(cfg, params, POLICIES["agentserve"], ecfg)
        eng.run(sessions)
        assert all(s.state == SessionState.FINISHED for s in sessions)
        assert (eng.hotpath_stats["prefill_tiles_streamed"] > 0
                and eng.hotpath_stats["prefill_tiles_skipped"] > 0)
        outcomes[backend] = [(s.last_token, s.output_tokens(), s.cached_len)
                             for s in sessions]
    assert outcomes["xla"] == outcomes["pallas"]


def test_alloc_resets_stale_ssm_state():
    """A freed slot's recurrent state must not seed the next session's
    prefill (attention KV is fenced by lengths; SSM state is not)."""
    pool = KVCachePool(HYBRID, 2, 32)
    s = pool.alloc()
    pool.cache = jax.tree.map(lambda l: l + 1.0, pool.cache)
    pool.free(s)
    s2 = pool.alloc()
    assert s2 == s
    for name, layer in pool.cache.items():
        for k, leaf in layer.items():
            rows = np.asarray(leaf[:, s2])
            if set(layer) <= POSITIONAL_CACHE_KEYS:
                np.testing.assert_array_equal(rows, np.ones_like(rows))
            else:
                np.testing.assert_array_equal(rows, np.zeros_like(rows))


def test_engine_hybrid_end_to_end():
    """The device-resident engine serves a Mamba/attention hybrid stack
    end to end (the seed engine was only ever exercised on dense)."""
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.policies import POLICIES
    from repro.serving.request import SessionState
    from repro.serving.workload import make_workload

    params = _params_for(HYBRID)
    ecfg = EngineConfig(num_slots=4, max_seq=256, cycle_budget=40,
                        granularity=8, b_min=8, b_max=32, b_init=16,
                        delta_b=8, control_interval_s=0.05, max_wall_s=90.0,
                        megastep_max=4, resume_batch_max=2)
    sessions = make_workload(2, vocab_size=HYBRID.vocab_size,
                             token_scale=0.03, num_system_prompts=1,
                             seed=5, stagger_s=0.05)
    eng = ServingEngine(HYBRID, params, POLICIES["agentserve"], ecfg)
    rep = eng.run(sessions)
    assert all(s.state == SessionState.FINISHED for s in sessions)
    for s in sessions:
        assert s.output_tokens() == sum(t.decode_len for t in s.turns)
    assert rep.total_output_tokens > 0
