"""Core AgentServe unit + property tests: phase classifier, Algorithm 1
control law, slot quantisation, dual-queue admission invariants."""
import pytest
from _hyp import given, settings, st

from repro.core.admission import AdmissionQueues, Job
from repro.core.phases import Phase, PhaseThresholds, classify
from repro.core.scheduler import SchedulerConfig, TPOTScheduler
from repro.core.slots import SlotManager


# ---------------------------------------------------------------------------
# phases
# ---------------------------------------------------------------------------

def test_classify_cold_vs_resume():
    thr = PhaseThresholds(min_cached_fraction=0.5, resume_max_new=256)
    assert classify(3000, 0, 3000, thr) == Phase.COLD_PREFILL
    assert classify(3056, 3000, 56, thr) == Phase.RESUME_PREFILL
    assert classify(3000, 3000, 0, thr) == Phase.DECODE
    # over-budget resume is treated as cold (paper §III-A)
    assert classify(4000, 3000, 1000, thr) == Phase.COLD_PREFILL
    # barely-cached prefix is still cold
    assert classify(3000, 100, 2900, thr) == Phase.COLD_PREFILL


@given(total=st.integers(1, 10_000), cached_frac=st.floats(0, 1))
def test_classify_total_consistency(total, cached_frac):
    cached = int(total * cached_frac)
    phase = classify(total, cached, total - cached)
    assert phase in (Phase.COLD_PREFILL, Phase.RESUME_PREFILL, Phase.DECODE)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def _sched(**kw):
    return TPOTScheduler(SchedulerConfig(
        total_resources=100, r_base=10, r_init=30, delta_r=10,
        b_min=16, b_max=512, b_init=128, delta_b=32,
        theta_low_ms=20.0, theta_high_ms=45.0, **kw))


def test_protection_mode():
    s = _sched()
    s.record_decode_step(0.100, steps=1)     # 100 ms TPOT > theta_high
    st_ = s.update()
    assert st_.mode == "protect"
    assert st_.b_prefill == 128 - 32
    assert st_.r_min == 40


def test_relaxation_mode():
    s = _sched()
    s.record_decode_step(0.005, steps=1)     # 5 ms < theta_low
    st_ = s.update()
    assert st_.mode == "relax"
    assert st_.b_prefill == 160
    assert st_.r_min == 20


def test_hold_band():
    s = _sched()
    s.record_decode_step(0.030, steps=1)     # between thresholds
    st_ = s.update()
    assert st_.mode == "hold"
    assert st_.b_prefill == 128 and st_.r_min == 30


@given(tpots=st.lists(st.floats(0.001, 0.5), min_size=1, max_size=60))
@settings(max_examples=50)
def test_bounds_never_violated(tpots):
    """B_prefill stays in [b_min, b_max]; R_min in [r_base, S] — whatever
    the TPOT trajectory (Algorithm 1 clamps, lines 5-9)."""
    s = _sched()
    for t in tpots:
        s.record_decode_step(t, steps=1)
        st_ = s.update()
        assert 16 <= st_.b_prefill <= 512
        assert 10 <= st_.r_min <= 100


def test_partition_sums_to_total():
    s = _sched()
    for t in [0.1, 0.1, 0.003, 0.1]:
        s.record_decode_step(t)
        s.update()
        d, p = s.partition()
        assert d + p == 100


# ---------------------------------------------------------------------------
# slots (Green Context analogue)
# ---------------------------------------------------------------------------

def test_slot_levels_discrete():
    sm = SlotManager(100, 10, lambda lv: f"exe{lv}")
    assert sm.levels == [10, 20, 30, 40, 50, 60, 70, 80, 90, 100]
    assert len(sm.stats.warmup_s) == 10     # pre-established offline


@given(target=st.integers(-50, 200))
def test_quantize_up_properties(target):
    """Assumption 2: allocation from {g,...,S}; overshoot delta < g."""
    sm = SlotManager(100, 10, lambda lv: lv, preestablish=False)
    lv = sm.quantize_up(target)
    assert lv in sm.levels
    clamped = max(min(target, 100), 10)
    assert lv >= clamped
    assert lv - clamped < 10                 # delta bounded by granularity


def test_rebind_counts_and_no_green_misses():
    sm = SlotManager(100, 10, lambda lv: lv, preestablish=True)
    sm.bind(35)
    sm.bind(35)      # same level: no new rebind
    sm.bind(55)
    assert sm.stats.rebinds == 2
    assert sm.stats.misses == 0
    ng = SlotManager(100, 10, lambda lv: lv, preestablish=False)
    ng.bind(35)
    assert ng.stats.misses == 1              # constructed on demand


# ---------------------------------------------------------------------------
# admission (Q_D / Q_P isolation invariant)
# ---------------------------------------------------------------------------

@given(jobs=st.lists(st.tuples(
    st.sampled_from([Phase.COLD_PREFILL, Phase.RESUME_PREFILL, Phase.DECODE]),
    st.integers(1, 600)), max_size=40))
def test_cold_never_in_decode_queue(jobs):
    s = _sched()
    q = AdmissionQueues(s)
    for i, (phase, n) in enumerate(jobs):
        q.enqueue(Job(session_id=i, phase=phase, new_len=n))
    for job in q.q_decode:
        assert job.phase != Phase.COLD_PREFILL
        if job.phase == Phase.RESUME_PREFILL:
            assert job.new_len <= s.state.b_prefill


def test_over_budget_resume_rerouted():
    s = _sched()
    q = AdmissionQueues(s)
    where = q.enqueue(Job(session_id=0, phase=Phase.RESUME_PREFILL,
                          new_len=s.state.b_prefill + 1))
    assert where == "Q_P"
    assert q.q_prefill[0].enqueued_cold
