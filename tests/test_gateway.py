"""Online gateway: async streaming sessions, open-loop arrivals,
watermark backpressure, tool-wait slot policy, and the HTTP/SSE front.

Every token-stream assertion goes through the scheduling-independent
greedy oracle (tests/_serving_util.py), so concurrency bugs that
corrupt KV state cannot hide behind 'all sessions finished'."""
import asyncio
import json

import jax
import numpy as np
import pytest
from _serving_util import events_by_session, oracle_streams

from repro.configs.base import ModelConfig
from repro.core.admission import WatermarkGate
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.gateway import (AgentGateway, GatewayConfig, Rejected,
                                   drive_open_loop)
from repro.serving.metrics import (OpenLoopReport, SLOThresholds,
                                   build_open_loop_report)
from repro.serving.policies import POLICIES
from repro.serving.request import SessionState
from repro.serving.workload import (load_arrival_trace,
                                    make_open_loop_workload,
                                    poisson_arrivals, save_arrival_trace)

TINY = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, *, num_slots=4):
    ecfg = EngineConfig(num_slots=num_slots, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        max_wall_s=float("inf"))
    return ServingEngine(TINY, params, POLICIES["agentserve"], ecfg)


def _sessions(n, *, seed=0, rate=8.0):
    return make_open_loop_workload(n, workload="react",
                                   vocab_size=TINY.vocab_size,
                                   token_scale=0.0625, seed=seed,
                                   rate_rps=rate)


def _drive(gateway, sessions, *, stop_timeout=60.0):
    arrivals = [s.ready_s for s in sessions]

    async def go():
        await gateway.start()
        run = await drive_open_loop(gateway, sessions, arrivals)
        await gateway.stop(timeout_s=stop_timeout)
        return run

    return asyncio.run(go())


# ---------------------------------------------------------------------------
# open-loop arrival processes
# ---------------------------------------------------------------------------

def test_poisson_arrivals_seeded_deterministic():
    a = poisson_arrivals(5.0, 50, seed=3)
    b = poisson_arrivals(5.0, 50, seed=3)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, poisson_arrivals(5.0, 50, seed=4))
    assert np.all(np.diff(a) > 0)
    # mean inter-arrival ~ 1/rate (loose: 50 samples)
    assert 0.5 / 5.0 < np.mean(np.diff(a)) < 2.0 / 5.0


def test_arrival_trace_roundtrip(tmp_path):
    path = str(tmp_path / "trace.txt")
    times = poisson_arrivals(2.0, 10, seed=1)
    save_arrival_trace(path, times)
    np.testing.assert_allclose(load_arrival_trace(path), times, atol=1e-8)
    sessions = make_open_loop_workload(10, vocab_size=64, token_scale=0.05,
                                      trace_path=path)
    assert [s.ready_s for s in sessions] == pytest.approx(list(times))


def test_open_loop_workload_argument_validation():
    with pytest.raises(ValueError):
        make_open_loop_workload(4, vocab_size=64)         # no source
    with pytest.raises(ValueError):
        make_open_loop_workload(4, vocab_size=64, rate_rps=1.0,
                                arrivals=np.arange(4.0))  # two sources
    with pytest.raises(ValueError):
        make_open_loop_workload(4, vocab_size=64, arrivals=np.arange(2.0))


# ---------------------------------------------------------------------------
# watermark gate
# ---------------------------------------------------------------------------

def test_watermark_gate_hysteresis():
    gate = WatermarkGate(high=4, low=2)
    assert gate.offer(3)                 # below high: admit
    assert not gate.offer(4)             # at high: close
    assert not gate.offer(3)             # hysteresis: still shedding
    assert gate.offer(2)                 # at low: reopen
    assert gate.admitted == 2 and gate.rejected == 2
    with pytest.raises(ValueError):
        WatermarkGate(high=2, low=2)


# ---------------------------------------------------------------------------
# gateway end-to-end
# ---------------------------------------------------------------------------

def test_gateway_streams_complete_interleaved_and_token_exact(tiny_params):
    """≥4 concurrent open-loop agents: every stream completes, events
    from different sessions interleave (live concurrency), and every
    stream is token-for-token the isolated greedy reference."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))
    sessions = _sessions(5, rate=6.0)
    run = _drive(gw, sessions)

    assert len(run.completed) == 5 and not run.rejected
    assert all(s.state == SessionState.FINISHED for s in run.completed)
    assert run.interleaved()
    assert gw.counters["tool_calls"] == sum(
        len(s.turns) - 1 for s in sessions)

    streams = events_by_session([ev for _, ev in run.events])
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=4, max_seq=512)
    for s in sessions:
        assert streams[s.session_id] == want[s.session_id]

    rep = build_open_loop_report("agentserve", run.completed, run.wall_s,
                                 6.0, rejected=0,
                                 thresholds=SLOThresholds(10.0, 2.0))
    assert rep.completed == 5
    assert rep.goodput_tok_s > 0
    assert np.isfinite(rep.queue_delay_p95_s)
    assert len(rep.row().split(",")) == len(OpenLoopReport.HEADER.split(","))


def test_gateway_backpressure_429_above_watermark(tiny_params):
    """A burst above the watermark is shed with 429-style results; the
    admitted subset still completes and streams correctly."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=1, low_watermark=0))
    sessions = _sessions(4, rate=1000.0)     # effectively simultaneous
    run = _drive(gw, sessions)

    assert len(run.rejected) >= 1
    assert len(run.completed) >= 1
    assert len(run.completed) + len(run.rejected) == 4
    assert gw.counters["rejected"] == len(run.rejected)
    assert gw.gate.rejected >= len(run.rejected)
    streams = events_by_session([ev for _, ev in run.events])
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=4, max_seq=512)
    for s in run.completed:
        assert streams[s.session_id] == want[s.session_id]


def test_gateway_rejected_result_shape(tiny_params):
    """submit() surfaces shedding as a 429-style value, not an
    exception."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=1, low_watermark=0))

    async def go():
        await gw.start()
        first = await gw.submit(_sessions(1, seed=11)[0])
        second = await gw.submit(_sessions(1, seed=12)[0])
        assert not isinstance(first, Rejected)
        assert isinstance(second, Rejected)
        assert second.status == 429 and second.occupancy >= 1
        async for _ in first.events():
            pass
        await gw.stop(timeout_s=60.0)

    asyncio.run(go())


def test_gateway_queue_admission_waits_instead_of_shedding(tiny_params):
    """admission='queue': over-watermark submissions wait for the gate
    to reopen (bounded) rather than shedding immediately."""
    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(
        high_watermark=2, low_watermark=1, admission="queue",
        queue_timeout_s=30.0))
    sessions = _sessions(4, rate=1000.0)
    run = _drive(gw, sessions)
    assert len(run.completed) == 4 and not run.rejected


def test_tool_wait_holds_slot_by_default(tiny_params):
    """hold policy: a session in TOOL_WAIT keeps its KV slot and cached
    length across the (gateway-clocked) tool wait."""
    eng = _engine(tiny_params)
    observed = []

    async def tool_fn(sess, turn_idx):
        observed.append((sess.slot, int(eng.pool.lengths[sess.slot])
                         if sess.slot >= 0 else -1))
        await asyncio.sleep(0.01)
        return None

    gw = AgentGateway(eng, GatewayConfig(high_watermark=32,
                                         tool_policy="hold"),
                      tool_fn=tool_fn)
    sessions = _sessions(2, rate=6.0)
    run = _drive(gw, sessions)

    assert len(run.completed) == 2
    assert observed and all(slot >= 0 and cached > 0
                            for slot, cached in observed)
    assert eng.hotpath_stats["parks"] == 0
    streams = events_by_session([ev for _, ev in run.events])
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=4, max_seq=512)
    for s in run.completed:
        assert streams[s.session_id] == want[s.session_id]


def test_tool_wait_release_under_pressure(tiny_params):
    """release policy: with more live agents than KV slots, TOOL_WAIT
    sessions give up their slot to waiting sessions (parks observed)
    and every resume is still token-exact — the restore is lossless."""
    eng = _engine(tiny_params, num_slots=2)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=64,
                                         tool_policy="release"))
    sessions = _sessions(3, rate=1000.0)     # all arrive together
    run = _drive(gw, sessions, stop_timeout=120.0)

    assert len(run.completed) == 3
    assert gw.counters["parked"] >= 1
    assert (eng.hotpath_stats["unparks"] == eng.hotpath_stats["parks"]
            >= 1)
    streams = events_by_session([ev for _, ev in run.events])
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=2, max_seq=512)
    for s in run.completed:
        assert streams[s.session_id] == want[s.session_id]


def test_tool_fn_failure_does_not_wedge_session(tiny_params):
    """A raising tool_fn must not strand the session in TOOL_WAIT: the
    error is counted and the session resumes with its scripted
    tokens — the client stream still terminates."""
    eng = _engine(tiny_params)

    async def tool_fn(sess, turn_idx):
        raise RuntimeError("tool exploded")

    gw = AgentGateway(eng, GatewayConfig(high_watermark=32),
                      tool_fn=tool_fn)
    sessions = _sessions(1, seed=9)
    run = _drive(gw, sessions)
    assert len(run.completed) == 1
    assert gw.counters["tool_errors"] == len(sessions[0].turns) - 1
    assert list(gw.completed_sessions) == run.completed


def test_tool_fn_can_replace_next_turn_prefill(tiny_params):
    """A real tool's output becomes the next turn's prefill tokens."""
    eng = _engine(tiny_params)
    marker = np.full((7,), 5, np.int32)

    async def tool_fn(sess, turn_idx):
        return marker

    gw = AgentGateway(eng, GatewayConfig(high_watermark=32),
                      tool_fn=tool_fn)
    sessions = _sessions(1, seed=6)
    run = _drive(gw, sessions)
    assert len(run.completed) == 1
    s = run.completed[0]
    for turn in s.turns[1:]:
        np.testing.assert_array_equal(turn.prefill_tokens, marker)
    # and the stream still matches the oracle for the *replaced* turns
    streams = events_by_session([ev for _, ev in run.events])
    want = oracle_streams(TINY, tiny_params, [s], num_slots=4, max_seq=512)
    assert streams[s.session_id] == want[s.session_id]


# ---------------------------------------------------------------------------
# HTTP/SSE front (stdlib asyncio, real sockets)
# ---------------------------------------------------------------------------

def test_http_sse_front_end_to_end(tiny_params):
    """Boot the SSE server on an ephemeral port; three concurrent
    clients stream tokens; /healthz and /stats respond; a tiny
    watermark then yields an observable 429."""
    from repro.launch.serve import (handle_connection, sse_get, sse_submit)

    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=32))

    async def go():
        await gw.start()
        server = await asyncio.start_server(
            lambda r, w: handle_connection(gw, TINY, 0.0625, r, w),
            "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]

        status, body = await sse_get("127.0.0.1", port, "/healthz")
        assert status == 200 and body == {"ok": True}

        results = await asyncio.gather(*(
            sse_submit("127.0.0.1", port,
                       {"workload": "react", "seed": 20 + i,
                        "token_scale": 0.05})
            for i in range(3)))
        for status, events in results:
            assert status == 200
            assert len(events) > 0
            assert {"session_id", "token", "t", "turn_idx"} <= set(
                events[0])

        status, stats = await sse_get("127.0.0.1", port, "/stats")
        assert status == 200 and stats["completed"] == 3.0

        status, _ = await sse_get("127.0.0.1", port, "/nope")
        assert status == 404

        server.close()
        await server.wait_closed()
        await gw.stop(timeout_s=60.0)

    asyncio.run(go())


def test_http_429_surfaced_over_sse(tiny_params):
    from repro.launch.serve import handle_connection, sse_submit

    eng = _engine(tiny_params)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=1, low_watermark=0))

    async def go():
        await gw.start()
        server = await asyncio.start_server(
            lambda r, w: handle_connection(gw, TINY, 0.05, r, w),
            "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        results = await asyncio.gather(*(
            sse_submit("127.0.0.1", port, {"seed": 30 + i})
            for i in range(4)))
        statuses = sorted(st for st, _ in results)
        assert statuses.count(429) >= 1
        assert statuses.count(200) >= 1
        server.close()
        await server.wait_closed()
        await gw.stop(timeout_s=60.0)

    asyncio.run(go())
