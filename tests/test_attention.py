"""Blocked (XLA-path) attention vs the naive oracle, incl. the
hand-written FlashAttention custom_vjp backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blocked_attention, decode_attention,
                                    bidirectional_attention)
from repro.kernels import ref

KEY = jax.random.PRNGKey(1)
K1, K2, K3 = jax.random.split(KEY, 3)


@pytest.mark.parametrize("B,Sq,H,Hk,hd,win", [
    (2, 40, 4, 2, 16, 0), (2, 40, 4, 2, 16, 12), (1, 33, 6, 3, 8, 0),
])
def test_forward_matches_naive(B, Sq, H, Hk, hd, win):
    q = jax.random.normal(K1, (B, Sq, H, hd))
    k = jax.random.normal(K2, (B, Sq, Hk, hd))
    v = jax.random.normal(K3, (B, Sq, Hk, hd))
    out = blocked_attention(q, k, v, causal=True, window=win, block_size=16)
    exp = ref.naive_attention(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("win", [0, 12])
def test_flash_vjp_matches_naive_grads(win):
    B, Sq, H, Hk, hd = 2, 40, 4, 2, 16
    q = jax.random.normal(K1, (B, Sq, H, hd))
    k = jax.random.normal(K2, (B, Sq, Hk, hd))
    v = jax.random.normal(K3, (B, Sq, Hk, hd))
    tgt = jax.random.normal(KEY, (B, Sq, H, hd))

    def f1(q, k, v):
        return jnp.sum((blocked_attention(q, k, v, causal=True, window=win,
                                          block_size=16) - tgt) ** 2)

    def f2(q, k, v):
        return jnp.sum((ref.naive_attention(q, k, v, causal=True,
                                            window=win) - tgt) ** 2)

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_resume_prefill_offsets():
    """q_offset semantics: chunk at offset o attends cache[:o] + itself."""
    B, S, H, Hk, hd = 2, 32, 4, 2, 16
    q_all = jax.random.normal(K1, (B, S, H, hd))
    k_all = jax.random.normal(K2, (B, S, Hk, hd))
    v_all = jax.random.normal(K3, (B, S, Hk, hd))
    full = ref.naive_attention(q_all, k_all, v_all, causal=True)
    off = 20
    chunk = blocked_attention(
        q_all[:, off:], k_all, v_all,
        q_offset=jnp.full((B,), off, jnp.int32),
        lengths=jnp.full((B,), S, jnp.int32), causal=True, block_size=8)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full[:, off:]),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("window", [0, 16])
def test_decode_attention_windowed(window):
    B, S, H, Hk, hd = 3, 64, 4, 2, 16
    q = jax.random.normal(K1, (B, 1, H, hd))
    kc = jax.random.normal(K2, (B, S, Hk, hd))
    vc = jax.random.normal(K3, (B, S, Hk, hd))
    lens = jnp.asarray([5, 30, 64], jnp.int32)
    out = decode_attention(q, kc, vc, lens, window=window, block_size=16)
    exp = ref.naive_decode_attention(q, kc, vc, lens, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


def test_bidirectional_with_padding():
    B, S, H, hd = 2, 40, 4, 16
    q = jax.random.normal(K1, (B, S, H, hd))
    k = jax.random.normal(K2, (B, S, H, hd))
    v = jax.random.normal(K3, (B, S, H, hd))
    lens = jnp.asarray([40, 17], jnp.int32)
    out = bidirectional_attention(q, k, v, lengths=lens, block_size=16)
    exp = ref.naive_attention(q, k, v, causal=False, lengths=lens)
    np.testing.assert_allclose(np.asarray(out[:, :17]),
                               np.asarray(exp[:, :17]),
                               rtol=3e-5, atol=3e-5)
