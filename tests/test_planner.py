"""Plan-based scheduling core (DESIGN.md §9).

Three layers of coverage:

1. **Pure planner tests** — policy invariants asserted directly on
   ``CyclePlan``s produced from synthetic ``EngineView``s: no engine,
   no device, microseconds per case.
2. **Preemptive SLO-class scheduling** — the ``priority`` planner
   end-to-end on the real engine: an interactive arrival preempts a
   batch cold prefill (KV parked on device), beats FCFS on interactive
   TTFT, and the preempted session still completes token-identically.
3. **Journal record/replay** — a recorded run's plans re-executed
   through the dispatcher reproduce the token events deterministically.
"""
import dataclasses

import jax
import pytest
from _serving_util import events_by_session, oracle_streams

from repro.configs.base import ModelConfig
from repro.core.phases import Phase
from repro.core.planner import (EngineView, JobView, PlanJournal,
                                ReplayPlanner, SessionView, make_planner)
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.policies import PLANNERS, POLICIES
from repro.serving.request import SessionState
from repro.serving.workload import make_workload

TINY = ModelConfig(name="tiny-planner", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")


# ---------------------------------------------------------------------------
# synthetic-view helpers
# ---------------------------------------------------------------------------

def mksv(sid, state, **kw):
    base = dict(session_id=sid, state=state, slot=-1, turn_idx=0,
                num_turns=3, cached_len=0, prefill_done=0,
                turn_prefill_len=200, decode_len=8, decoded=0,
                shared_prefix_len=0, ready_s=0.0)
    base.update(kw)
    return SessionView(**base)


def mkview(**kw):
    base = dict(now=10.0, next_ctrl=10.05, tpot_step_ms=5.0, r_min=16,
                b_prefill=32, cycle_budget=80, granularity=8, r_base=8,
                max_seq=512, free_slots=2, slot_lengths=(0, 0, 0, 0),
                sessions=(), q_decode=(), q_prefill=(),
                buckets=(8, 16, 32, 64, 128), resume_levels=(1, 2, 4),
                cold_levels=(2, 4), megastep_levels=(2, 4, 6, 8),
                chunk_tok_s={}, autotune=True)
    base.update(kw)
    return EngineView(**base)


def job(sid, phase=Phase.COLD_PREFILL, new_len=200):
    return JobView(session_id=sid, phase=phase, new_len=new_len)


# ---------------------------------------------------------------------------
# pure planner tests (no engine, no device)
# ---------------------------------------------------------------------------

def test_fcfs_never_interleaves_decode_under_prefill():
    """HOL blocking is FCFS's defining behaviour: with any prefill in
    flight the plan contains no decode dispatch; the queue head runs to
    completion instead."""
    p = make_planner(POLICIES["fcfs"])
    view = mkview(
        sessions=(mksv(0, "decoding", slot=0, decoded=2),
                  mksv(1, "prefilling", slot=1, prefill_done=40)),
        q_prefill=(job(1, new_len=160),), free_slots=2)
    plan = p.plan(view)
    assert plan.decode is None
    assert len(plan.prefill) == 1 and plan.prefill[0].kind == "whole"
    assert plan.prefill[0].session_ids == (1,)
    # with the prefill queue empty, decode proceeds
    plan2 = p.plan(mkview(sessions=(mksv(0, "decoding", slot=0),)))
    assert plan2.decode is not None and plan2.decode.session_ids == (0,)


def test_fcfs_routes_everything_to_prefill_queue():
    p = make_planner(POLICIES["fcfs"])
    view = mkview(sessions=(
        mksv(0, "tool_call", slot=0, cached_len=300, turn_prefill_len=8,
             turn_idx=1, ready_s=0.0),))
    plan = p.plan(view)
    assert len(plan.admissions) == 1
    assert not plan.admissions[0].to_decode_queue


def test_pd_static_never_changes_partition():
    """pd_static's partition is frozen: the controller never updates and
    the bound slot level is the quantised static point, whatever the
    view's TPOT says."""
    p = make_planner(POLICIES["pd_static"])
    assert p.static_r_min(80, 8) == 40          # 0.5 * C on the grid
    for now, next_ctrl, tpot in [(0.0, 1.0, 5.0), (2.0, 1.0, 500.0)]:
        assert not p.plan_control(now, next_ctrl).update
    levels = {p.plan(mkview(r_min=40, tpot_step_ms=t)).slot_level
              for t in (1.0, 50.0, 500.0)}
    assert levels == {40}
    # resumes are never fused into the decode queue
    view = mkview(r_min=40, sessions=(
        mksv(0, "tool_call", slot=0, cached_len=300, turn_prefill_len=8,
             turn_idx=1),))
    plan = p.plan(view)
    assert plan.admissions and not plan.admissions[0].to_decode_queue
    assert plan.admissions[0].phase == Phase.RESUME_PREFILL  # still split


def test_chunked_never_exceeds_fixed_budget():
    """The chunked baseline's scheduled prefill work per cycle is capped
    by its fixed chunk budget — for the plain chunk path, the autotuned
    path, and the packed path."""
    p = make_planner(POLICIES["chunked"])
    budget = int(0.5 * 80) // 8 * 8             # fixed_chunk_frac * C
    views = [
        mkview(sessions=(mksv(0, "prefilling", slot=0),),
               q_prefill=(job(0),)),
        mkview(sessions=(mksv(0, "prefilling", slot=0),),
               q_prefill=(job(0),),
               chunk_tok_s={16: 100.0, 32: 900.0, 64: 950.0}),
        mkview(sessions=(mksv(0, "prefilling", slot=0),
                         mksv(1, "prefilling", slot=1)),
               q_prefill=(job(0), job(1))),
    ]
    for view in views:
        for op in p.plan(view).prefill:
            if op.kind == "pack":
                assert op.shape * len(op.session_ids) <= budget
            else:
                assert op.shape * op.reps <= budget
            assert not op.reclaim                # no slot reclaim either


def test_agentserve_isolation_and_budget_routing():
    """Cold prefills never enter Q_D; resumes split on B_prefill."""
    p = make_planner(POLICIES["agentserve"])
    cold = mksv(0, "waiting_prefill", turn_prefill_len=300)
    small_resume = mksv(1, "tool_call", slot=1, cached_len=300,
                        turn_prefill_len=8, turn_idx=1)
    big_resume = mksv(2, "tool_call", slot=2, cached_len=300,
                      turn_prefill_len=120, turn_idx=2)
    plan = p.plan(mkview(b_prefill=32, free_slots=4,
                         sessions=(cold, small_resume, big_resume)))
    routed = {a.session_id: a for a in plan.admissions}
    assert not routed[0].to_decode_queue
    assert routed[0].phase == Phase.COLD_PREFILL
    assert routed[1].to_decode_queue            # 8 <= B_prefill
    assert not routed[2].to_decode_queue        # 120 > B_prefill
    assert routed[2].phase == Phase.RESUME_PREFILL


def test_agentserve_megastep_only_when_queues_empty():
    p = make_planner(POLICIES["agentserve"])
    dec = (mksv(0, "decoding", slot=0, decoded=1, decode_len=20),)
    quiet = p.plan(mkview(sessions=dec, tpot_step_ms=1.0,
                          next_ctrl=10.05, now=10.0))
    assert quiet.decode.megastep_target > 1     # fuse up to the boundary
    busy = p.plan(mkview(sessions=dec + (
        mksv(1, "prefilling", slot=1),), q_prefill=(job(1),)))
    assert busy.decode is not None
    assert busy.decode.megastep_target == 0     # queues non-empty


def test_agentserve_admission_respects_free_slots():
    p = make_planner(POLICIES["agentserve"])
    waiting = tuple(mksv(i, "waiting_prefill") for i in range(4))
    plan = p.plan(mkview(sessions=waiting, free_slots=2))
    assert len(plan.admissions) == 2            # backpressure on the rest
    assert [a.session_id for a in plan.admissions] == [0, 1]


def test_priority_preempts_cold_under_interactive_arrival():
    """The tentpole capability at planner level: zero free slots + a
    ready interactive arrival => the batch cold prefill with the most
    remaining work is suspended and the interactive session admitted in
    the same plan."""
    p = make_planner(PLANNERS["priority"])
    batch_a = mksv(0, "prefilling", slot=0, prefill_done=20,
                   turn_prefill_len=300)
    batch_b = mksv(1, "prefilling", slot=1, prefill_done=150,
                   turn_prefill_len=300)
    inter = mksv(2, "waiting_prefill", slo="interactive")
    view = mkview(free_slots=0,
                  sessions=(batch_a, batch_b, inter),
                  q_prefill=(job(0, new_len=280), job(1, new_len=150)))
    plan = p.plan(view)
    assert plan.preempt == (0,)                 # most remaining work
    admitted = [a.session_id for a in plan.admissions]
    assert admitted == [2]                      # interactive got the slot
    # without the interactive arrival: no preemption
    calm = dataclasses.replace(view, sessions=(batch_a, batch_b))
    assert p.plan(calm).preempt == ()
    # batch arrivals never preempt
    batch_arrival = dataclasses.replace(
        view, sessions=(batch_a, batch_b, mksv(2, "waiting_prefill")))
    assert p.plan(batch_arrival).preempt == ()
    # cold-only invariant: an over-budget *resume* sitting in Q_P keeps
    # its phase and is never a preemption victim
    resume_only = dataclasses.replace(
        view, q_prefill=(job(0, phase=Phase.RESUME_PREFILL, new_len=280),
                         job(1, phase=Phase.RESUME_PREFILL, new_len=150)))
    assert p.plan(resume_only).preempt == ()


def test_priority_unsuspends_oldest_suspension_first():
    p = make_planner(PLANNERS["priority"])
    view = mkview(free_slots=1, sessions=(
        mksv(0, "prefill_paused", paused_seq=7),    # suspended later...
        mksv(1, "prefill_paused", paused_seq=3)))   # ...than this one
    assert p.plan(view).unsuspend == (1,)


def test_priority_resumes_suspended_when_pressure_clears():
    p = make_planner(PLANNERS["priority"])
    paused = mksv(0, "prefill_paused", prefill_done=20,
                  turn_prefill_len=300)
    plan = p.plan(mkview(free_slots=1, sessions=(paused,)))
    assert plan.unsuspend == (0,)
    # interactive demand outranks the suspended batch prefill
    contended = mkview(free_slots=1, sessions=(
        paused, mksv(1, "waiting_prefill", slo="interactive")))
    plan2 = p.plan(contended)
    assert plan2.unsuspend == ()
    assert [a.session_id for a in plan2.admissions] == [1]


def test_priority_serves_interactive_prefill_first():
    p = make_planner(PLANNERS["priority"])
    view = mkview(
        sessions=(mksv(0, "prefilling", slot=0),
                  mksv(1, "prefilling", slot=1, slo="interactive")),
        q_prefill=(job(0), job(1)), cold_levels=())   # no packing: serial
    plan = p.plan(view)
    assert plan.prefill and plan.prefill[0].session_ids == (1,)


# ---------------------------------------------------------------------------
# priority end-to-end: preemption on the real engine beats FCFS TTFT
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


INTERACTIVE_ARRIVAL_S = 0.05                    # arrives under full load


def _mixed_workload():
    """Two batch agents saturating both KV slots, one interactive agent
    arriving mid-cold-prefill."""
    sessions = make_workload(3, workload="react",
                             vocab_size=TINY.vocab_size,
                             token_scale=0.0625, num_system_prompts=1,
                             seed=11, stagger_s=0.0)
    sessions[2].ready_s = INTERACTIVE_ARRIVAL_S
    for s in sessions[:2]:
        s.slo_class = "batch"
    sessions[2].slo_class = "interactive"
    return sessions


def _interactive_ttft(sessions):
    # measured against the submission time, which includes any slot/HOL
    # wait (the engine rewrites Session.ready_s on every later turn, so
    # the original arrival must come from the workload constant)
    return sessions[2].first_token_s[0] - INTERACTIVE_ARRIVAL_S


def _run_policy(params, policy):
    ecfg = EngineConfig(num_slots=2, max_seq=512, cycle_budget=40,
                        granularity=8, b_min=8, b_max=64, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        max_wall_s=120.0, record_events=True)
    sessions = _mixed_workload()
    eng = ServingEngine(TINY, params, PLANNERS[policy], ecfg)
    rep = eng.run(sessions)
    assert all(s.state == SessionState.FINISHED for s in sessions)
    return eng, sessions, rep


def test_priority_preemption_end_to_end(tiny_params):
    eng, sessions, _ = _run_policy(tiny_params, "priority")
    # the interactive arrival actually forced a preemption + later resume
    assert eng.hotpath_stats["preemptions"] >= 1
    assert eng.hotpath_stats["preempt_resumes"] >= 1
    assert eng.hotpath_stats["preempt_resumes"] == \
        eng.hotpath_stats["preemptions"]

    # the preempted session (and everyone else) still decodes the exact
    # greedy reference stream — park/unpark is lossless mid-prefill
    streams = events_by_session(eng.event_log)
    want = oracle_streams(TINY, tiny_params, sessions,
                          num_slots=eng.ecfg.num_slots,
                          max_seq=eng.ecfg.max_seq)
    for s in sessions:
        assert streams[s.session_id] == want[s.session_id]
        assert s.output_tokens() == sum(t.decode_len for t in s.turns)

    # interactive TTFT beats head-of-line-blocking FCFS on the same load
    eng_f, sessions_f, _ = _run_policy(tiny_params, "fcfs")
    assert eng_f.hotpath_stats["preemptions"] == 0
    assert _interactive_ttft(sessions) < _interactive_ttft(sessions_f)


# ---------------------------------------------------------------------------
# journal record/replay determinism
# ---------------------------------------------------------------------------

def _golden_cfg():
    return EngineConfig(num_slots=4, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        max_wall_s=60.0, record_events=True)


def _workload():
    return make_workload(3, workload="react", vocab_size=TINY.vocab_size,
                         token_scale=0.0625, num_system_prompts=1,
                         seed=0, stagger_s=0.05)


def test_journal_replay_reproduces_token_events(tiny_params):
    """Record a live agentserve run's plans, then replay the journal
    against a fresh engine + fresh (identical) workload: every session's
    token stream must come out identical, without the replay consulting
    the wall clock for a single decision."""
    eng = ServingEngine(TINY, tiny_params, POLICIES["agentserve"],
                        _golden_cfg())
    sessions = _workload()
    eng.run(sessions)
    recorded = events_by_session(eng.event_log)
    assert len(eng.journal.records) > 0
    assert eng.journal.dropped == 0

    replayer = ReplayPlanner(eng.journal, spec=POLICIES["agentserve"])
    eng2 = ServingEngine(TINY, tiny_params, replayer, _golden_cfg())
    sessions2 = _workload()
    eng2.run(sessions2)
    replayed = events_by_session(eng2.event_log)

    assert set(replayed) == set(recorded)
    for sid in recorded:
        assert replayed[sid] == recorded[sid]
    for s, s2 in zip(sessions, sessions2):
        assert s2.output_tokens() == s.output_tokens()
        assert int(s2.last_token) == int(s.last_token)


def test_journal_summary_and_trace_breakdown(tiny_params):
    """The executed-plan journal feeds per-policy reporting, and the
    cycle trace attributes Q_P occupancy to cold vs resume phases."""
    eng = ServingEngine(TINY, tiny_params, POLICIES["agentserve"],
                        _golden_cfg())
    eng.run(_workload())
    s = eng.journal.summary()
    assert s["cycles"] == len(eng.journal.records) > 0
    assert s["admissions"] > 0 and s["decode_cycles"] > 0
    assert s["mean_chunk"] > 0
    assert all("q_p_cold" in t and "q_p_resume" in t for t in eng.trace)
    assert any(t["q_p_cold"] > 0 for t in eng.trace)
    # occupancy breakdown is consistent
    for t in eng.trace:
        assert t["q_p_cold"] + t["q_p_resume"] == t["q_p"]


def test_replay_planner_raises_when_exhausted():
    rp = ReplayPlanner(PlanJournal(records=[]))
    with pytest.raises(RuntimeError, match="exhausted"):
        rp.plan_control(0.0, 1.0)


# ---------------------------------------------------------------------------
# simulator: planner-unified semantics + fractional TPOT accounting
# ---------------------------------------------------------------------------

def _slow_profile(decode_rate: float):
    import numpy as np
    from repro.core.competitive import ThroughputProfile
    levels = np.arange(10, 110, 10)
    return ThroughputProfile(levels=levels,
                             mu_decode=np.full(10, decode_rate),
                             mu_cold=200.0 * np.ones(10),
                             mu_resume=200.0 * np.ones(10))


def test_simulator_slow_streams_keep_tpot_samples():
    """Regression: a decode stream producing <0.5 tok per dt used to
    round every interval's sample count to zero and vanish from the
    TPOT percentiles; fractional tokens must accumulate instead."""
    from repro.serving.simulator import SimSession, simulate
    sess = [SimSession(cold_len=40,
                       turns=[dict(resume_len=0, decode_len=10,
                                   tool_s=0.0)])]
    # 4 tok/s at dt=0.05 => 0.2 tok per interval: the old accounting
    # recorded int(round(0.2)) == 0 samples forever
    res = simulate(_slow_profile(4.0), sess, planner="agentserve",
                   dt=0.05, max_t=60.0)
    assert len(res.tpots) == 10                  # one sample per token
    assert all(abs(t - 0.25) < 1e-6 for t in res.tpots)


def test_simulator_consumes_planner_objects():
    """The simulator reads policy semantics off the same CyclePlanner
    the engine executes — FCFS ordering comes from the planner, and a
    planner instance (not a name) is accepted directly."""
    from repro.serving.simulator import SimSession, simulate
    mk = lambda at: SimSession(cold_len=100, arrival_s=at,
                               turns=[dict(resume_len=0, decode_len=5,
                                           tool_s=0.0)])
    for planner in (make_planner(POLICIES["fcfs"]),
                    make_planner(PLANNERS["priority"]), "chunked"):
        res = simulate(_slow_profile(50.0), [mk(0.0), mk(0.1)],
                       planner=planner, max_t=60.0)
        assert res.prefill_tokens_served > 0 and res.tpots
