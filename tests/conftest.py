"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device (dry-run contract §0); only launch/dryrun.py sets the
512-device flag."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig


TINY = ModelConfig(
    name="tiny-test", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
    tie_embeddings=True, source="test")


@pytest.fixture(scope="session")
def tiny_cfg():
    return TINY


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    from repro.models import init_params
    return init_params(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
