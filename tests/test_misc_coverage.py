"""Coverage beyond the core path: the paper's own evaluation models,
checkpoint round-trip, M-RoPE properties, config registry sanity, and
the HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import parse_collectives
from repro.configs.base import (ARCH_MODULES, all_configs, get_config,
                                get_smoke_config)
from repro.models import forward_train, init_params
from repro.models.rope import apply_mrope, apply_rope, text_positions3
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, init_opt_state

KEY = jax.random.PRNGKey(4)


# ---------------------------------------------------------------------------
# the paper's own testbed models (§IV-A) are first-class configs too
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen2.5-7b", "llama3-8b"])
def test_paper_models_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    logits, _ = forward_train(params, cfg, toks, moe_mode="dense")
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()


def test_published_param_counts():
    """Exact-config param counts within 20% of the published sizes."""
    expected = {
        "mixtral-8x22b": 141e9, "starcoder2-15b": 15e9,
        "jamba-1.5-large-398b": 398e9, "mamba2-780m": 0.78e9,
        "olmoe-1b-7b": 6.9e9, "qwen2-vl-7b": 7.6e9,
        "smollm-360m": 0.36e9, "llama3.2-3b": 3.2e9,
        "llama3-8b": 8.0e9, "qwen2.5-7b": 7.6e9,
    }
    for name, want in expected.items():
        got = get_config(name).param_count()
        assert abs(got - want) / want < 0.20, (name, got, want)


def test_registry_complete():
    assert len(ARCH_MODULES) == 13      # 10 assigned + 3 paper models
    for name, cfg in all_configs().items():
        assert cfg.name == name
        assert cfg.source


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny_cfg, tiny_params):
    opt_cfg = AdamWConfig()
    opt = init_opt_state(opt_cfg, tiny_params)
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, tiny_params, opt, step=7, meta={"arch": "tiny"})
    p2, o2, step = load_checkpoint(path, tiny_params, opt)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tiny_params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


# ---------------------------------------------------------------------------
# M-RoPE
# ---------------------------------------------------------------------------

def test_mrope_degenerates_to_rope_for_text():
    """Qwen2-VL property: equal (t,h,w) components == 1-D RoPE with a
    section-permuted frequency order — norms and inner products match."""
    B, S, H, hd = 2, 8, 2, 32
    x = jax.random.normal(KEY, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    r1 = apply_rope(x, pos, 10_000.0)
    r3 = apply_mrope(x, text_positions3(pos), 10_000.0, (6, 5, 5))
    # rotations preserve pairwise norms; for degenerate positions the
    # rotation angle sets are identical (perm of frequency assignment)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r1), axis=-1),
        np.linalg.norm(np.asarray(r3), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """RoPE inner products depend only on relative distance."""
    B, H, hd = 1, 1, 16
    q = jax.random.normal(KEY, (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(5), (B, 1, H, hd))

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.full((B, 1), pq, jnp.int32), 1e4)
        kr = apply_rope(k, jnp.full((B, 1), pk, jnp.int32), 1e4)
        return float(jnp.sum(qr * kr))

    assert dot_at(3, 1) == pytest.approx(dot_at(13, 11), rel=1e-4)
    assert dot_at(5, 0) != pytest.approx(dot_at(9, 0), rel=1e-3)


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

HLO_SNIPPET = """
ENTRY %main (p0: bf16[128,256]) -> bf16[128,256] {
  %ag = bf16[128,256]{1,0} all-gather(bf16[8,256]{1,0} %p0), replica_groups={{0,1}}
  %ar = f32[64,64]{1,0} all-reduce(f32[64,64]{1,0} %x), to_apply=%add
  ROOT %out = bf16[128,256]{1,0} copy(%ag)
}
%body (p: s32[]) -> s32[] {
  %cp = bf16[32,32]{1,0} collective-permute(bf16[32,32]{1,0} %y)
}
"""


def test_parse_collectives_factors_and_trips():
    stats = parse_collectives(HLO_SNIPPET, loop_trip_count=4)
    # all-gather: result bytes = 128*256*2
    assert stats.bytes_by_kind["all-gather"] == 128 * 256 * 2
    # all-reduce: 2x result bytes (RS + AG phases)
    assert stats.bytes_by_kind["all-reduce"] == 2 * 64 * 64 * 4
    # collective-permute sits in a non-entry computation -> x trip count
    assert stats.count_by_kind["collective-permute"] == 4
    assert stats.bytes_by_kind["collective-permute"] == 4 * 32 * 32 * 2
