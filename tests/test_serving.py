"""Serving substrate: KV pool invariants (hypothesis), workload Table-I
distributions, metrics, an end-to-end engine run per policy, and the
reactor-refactor regression guard (golden trace + oracle streams)."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from _serving_util import events_by_session, oracle_streams

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvcache import KVCachePool
from repro.serving.metrics import SLOThresholds, collect_tpots
from repro.serving.policies import POLICIES
from repro.serving.reactor import EngineReactor, HandleStatus
from repro.serving.request import SessionState
from repro.serving.workload import make_workload, table1_statistics

GOLDEN = pathlib.Path(__file__).parent / "golden" / "serving_golden.json"

TINY = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")


# ---------------------------------------------------------------------------
# KV cache pool
# ---------------------------------------------------------------------------

def test_pool_alloc_free_cycle():
    pool = KVCachePool(TINY, 4, 64)
    slots = [pool.alloc() for _ in range(4)]
    assert len(set(slots)) == 4 and pool.free_slots == 0
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.free(slots[0])
    assert pool.alloc() == slots[0]


def test_prefix_snapshot_roundtrip():
    pool = KVCachePool(TINY, 4, 64)
    s = pool.alloc()
    toks = np.arange(10, dtype=np.int32)
    # write something recognisable into the slot
    pool.cache = jax.tree.map(lambda l: l.at[:, s].set(1.0), pool.cache)
    pool.lengths[s] = 10
    pool.register_prefix(s, toks)
    d = pool.alloc()
    entry = pool.lookup(toks)
    assert entry is not None and entry.length == 10
    pool.restore_prefix(d, entry)
    assert pool.lengths[d] == 10
    for leaf in jax.tree_util.tree_leaves(pool.cache):
        np.testing.assert_array_equal(np.asarray(leaf[:, d]),
                                      np.asarray(leaf[:, s]))
    assert pool.lookup(np.arange(11, dtype=np.int32)) is None


def test_prefix_eviction_is_lru():
    """Eviction must be least-recently-used, not min-refs: under
    min-refs an old hot prefix (many hits) can never be displaced and a
    fresh deployment's prompt is thrashed forever."""
    pool = KVCachePool(TINY, 4, 64, max_prefix_entries=2)
    slot = pool.alloc()
    a = np.arange(5, dtype=np.int32)
    b = np.arange(6, dtype=np.int32)
    c = np.arange(7, dtype=np.int32)

    def reg(tokens):
        pool.lengths[slot] = len(tokens)
        pool.register_prefix(slot, tokens)

    reg(a)
    for _ in range(3):                      # a: hot (3 hits) but stale
        assert pool.lookup(a) is not None
    reg(b)                                  # b: fresh, zero hits
    reg(c)                                  # at capacity -> evict LRU (a)
    assert pool.lookup(b) is not None       # fresh prefix survives
    assert pool.lookup(c) is not None
    assert pool.lookup(a) is None           # stale-hot one was evicted
    assert pool.stats["evictions"] == 1


def test_register_prefix_refresh_on_exact_key_hit():
    """Re-registering an already-cached prefix must not re-snapshot or
    evict another entry at capacity — it only refreshes recency (so the
    re-registered prefix is treated as just-used by LRU eviction)."""
    pool = KVCachePool(TINY, 4, 64, max_prefix_entries=2)
    slot = pool.alloc()
    a = np.arange(5, dtype=np.int32)
    b = np.arange(6, dtype=np.int32)
    c = np.arange(7, dtype=np.int32)

    def reg(tokens):
        pool.lengths[slot] = len(tokens)
        pool.register_prefix(slot, tokens)

    reg(a)
    reg(b)                                  # at capacity, no eviction yet
    reg(a)                                  # exact-key hit: refresh only
    assert pool.stats["prefix_refreshes"] == 1
    assert pool.stats["evictions"] == 0     # the old code evicted here
    reg(c)                                  # LRU is now b, not a
    assert pool.lookup(a) is not None
    assert pool.lookup(c) is not None
    assert pool.lookup(b) is None
    assert pool.stats["evictions"] == 1


@given(mask=st.lists(st.booleans(), min_size=4, max_size=4))
@settings(max_examples=20, deadline=None)
def test_commit_mask_protects_inactive(mask):
    """commit() must only update rows where mask is True — the property
    that keeps inactive sessions' SSM states untouched."""
    pool = KVCachePool(TINY, 4, 16)
    old = pool.cache
    new = jax.tree.map(lambda l: l + 1.0, old)
    pool.commit(new, np.asarray(mask))
    for leaf_new, leaf_cur in zip(jax.tree_util.tree_leaves(new),
                                  jax.tree_util.tree_leaves(pool.cache)):
        for b, m in enumerate(mask):
            expect = leaf_new[:, b] if m else leaf_new[:, b] * 0.0
            np.testing.assert_allclose(np.asarray(leaf_cur[:, b]),
                                       np.asarray(expect))


# ---------------------------------------------------------------------------
# workload (Table I)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload,res_rng,dec_rng", [
    ("react", (30, 127), (27, 127)),
    ("plan_execute", (125, 421), (33, 141)),
])
def test_table1_distributions(workload, res_rng, dec_rng):
    stats = table1_statistics(workload, n=100)
    assert 2500 <= stats["cold_prefill"]["min"]
    assert stats["cold_prefill"]["max"] <= 3500 + 3500 // 8
    assert res_rng[0] <= stats["resume_prefill"]["min"]
    assert stats["resume_prefill"]["max"] <= res_rng[1]
    assert dec_rng[0] <= stats["decode"]["min"]
    assert stats["decode"]["max"] <= dec_rng[1]


def test_workload_scaling_and_shared_prefix():
    ws = make_workload(4, vocab_size=128, token_scale=0.25,
                       num_system_prompts=1, seed=3)
    assert all(s.shared_prefix_len > 0 for s in ws)
    a, b = ws[0], ws[1]
    pa = a.turns[0].prefill_tokens[:min(a.shared_prefix_len,
                                        b.shared_prefix_len)]
    pb = b.turns[0].prefill_tokens[:len(pa)]
    np.testing.assert_array_equal(pa, pb)   # shared system prompt


# ---------------------------------------------------------------------------
# engine end-to-end (one per policy)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine_parts():
    params = init_params(TINY, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=4, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05, max_wall_s=60.0)
    return params, ecfg


@pytest.mark.parametrize("policy", ["agentserve", "pd_static", "chunked",
                                    "fcfs"])
def test_engine_end_to_end(tiny_engine_parts, policy):
    params, ecfg = tiny_engine_parts
    sessions = make_workload(3, workload="react", vocab_size=TINY.vocab_size,
                             token_scale=0.0625, num_system_prompts=1,
                             seed=0, stagger_s=0.05)
    eng = ServingEngine(TINY, params, POLICIES[policy], ecfg)
    rep = eng.run(sessions, SLOThresholds(ttft_s=5.0, tpot_s=1.0))
    assert all(s.state == SessionState.FINISHED for s in sessions)
    assert rep.total_output_tokens > 0
    assert rep.throughput_tok_s > 0
    assert np.isfinite(rep.ttft_p50_s) and np.isfinite(rep.tpot_p50_s)
    # every turn produced its full decode burst
    for s in sessions:
        assert s.output_tokens() == sum(t.decode_len for t in s.turns)


def test_agentserve_isolation_invariant(tiny_engine_parts):
    """Cold prefills never enter Q_D (checked via the admission log)."""
    params, ecfg = tiny_engine_parts
    sessions = make_workload(3, vocab_size=TINY.vocab_size,
                             token_scale=0.0625, seed=1)
    eng = ServingEngine(TINY, params, POLICIES["agentserve"], ecfg)
    eng.run(sessions)
    assert eng.slots.stats.rebinds >= 1
    assert eng.slots.stats.misses == 0      # everything pre-established


def test_no_green_pays_on_demand(tiny_engine_parts):
    params, ecfg = tiny_engine_parts
    sessions = make_workload(2, vocab_size=TINY.vocab_size,
                             token_scale=0.0625, seed=2)
    eng = ServingEngine(TINY, params, POLICIES["no_green"], ecfg)
    eng.run(sessions)
    assert eng.slots.stats.misses >= 1      # built inside the serving path


# ---------------------------------------------------------------------------
# reactor refactor regression guard (golden trace + oracle streams)
# ---------------------------------------------------------------------------

def _golden_workload_and_engine(params, record_events=True):
    g = json.loads(GOLDEN.read_text())
    w = g["workload"]
    sessions = make_workload(w["n"], workload=w["workload"],
                             vocab_size=w["vocab_size"],
                             token_scale=w["token_scale"],
                             num_system_prompts=w["num_system_prompts"],
                             seed=w["seed"], stagger_s=w["stagger_s"])
    ecfg = EngineConfig(**g["engine_cfg"], record_events=record_events)
    eng = ServingEngine(TINY, params, POLICIES["agentserve"], ecfg)
    return g, sessions, eng


def test_run_matches_pre_refactor_golden(tiny_engine_parts):
    """run() rebuilt on the reactor must reproduce the pre-refactor
    engine's golden trace: the deterministic ServingReport fields and
    per-session outcomes recorded from commit 8559b36, plus
    token-for-token identity of the emitted streams against the
    scheduling-independent oracle."""
    params, _ = tiny_engine_parts
    g, sessions, eng = _golden_workload_and_engine(params)
    rep = eng.run(sessions)

    assert rep.policy == g["policy"]
    assert rep.num_sessions == g["num_sessions"]
    assert rep.total_output_tokens == g["total_output_tokens"]
    assert eng.slots.stats.misses == g["slot_misses"]
    for s, gs in zip(sessions, g["per_session"]):
        assert s.session_id == gs["session_id"]
        assert s.output_tokens() == gs["output_tokens"]
        assert len(s.request_arrivals) == gs["num_requests"]
        assert len(s.first_token_s) == gs["num_first_tokens"]
        assert int(s.last_token) == gs["final_token"]
        assert [t.decode_len for t in s.turns] == gs["turn_decode_lens"]

    # token-for-token: the event stream run() recorded must equal the
    # isolated greedy reference for every session
    streams = events_by_session(eng.event_log)
    want = oracle_streams(TINY, params, sessions,
                          num_slots=eng.ecfg.num_slots,
                          max_seq=eng.ecfg.max_seq)
    for s in sessions:
        assert streams[s.session_id] == want[s.session_id]
        assert len(streams[s.session_id]) == s.output_tokens()


def test_reactor_manual_drive_matches_run(tiny_engine_parts):
    """Driving submit/step/poll by hand must produce the same streams
    and session outcomes as the packaged run() loop."""
    params, _ = tiny_engine_parts
    g, sessions, eng = _golden_workload_and_engine(params)
    reactor = EngineReactor(eng)
    handles = [reactor.submit(s, arrival_s=s.ready_s) for s in sessions]
    events = reactor.drain(max_wall_s=60.0)

    assert all(reactor.poll(h) is HandleStatus.DONE for h in handles)
    # poll-side delivery: every emitted event is also on its handle
    assert sum(len(reactor.take_events(h)) for h in handles) == len(events)
    streams = events_by_session(events)
    want = oracle_streams(TINY, params, sessions,
                          num_slots=eng.ecfg.num_slots,
                          max_seq=eng.ecfg.max_seq)
    for s, gs in zip(sessions, g["per_session"]):
        assert streams[s.session_id] == want[s.session_id]
        assert s.output_tokens() == gs["output_tokens"]
        assert int(s.last_token) == gs["final_token"]


def test_park_unpark_preserves_resume(tiny_engine_parts):
    """A TOOL_WAIT session whose KV slot is released under pressure must
    resume with a bit-identical stream: park snapshots the slot
    (attention KV + any SSM state), the slot serves another session,
    and unpark restores it losslessly."""
    params, ecfg = tiny_engine_parts
    sessions = make_workload(2, vocab_size=TINY.vocab_size,
                             token_scale=0.0625, seed=4, stagger_s=0.0)
    for s in sessions:
        s.external_tools = True         # gateway-style tool clock
    eng = ServingEngine(TINY, params, POLICIES["agentserve"], ecfg)
    reactor = EngineReactor(eng)
    handles = [reactor.submit(s) for s in sessions]
    events = []
    parked_once = False
    for _ in range(200_000):
        events.extend(reactor.step())
        for s in sessions:
            if s.state != SessionState.TOOL_WAIT:
                continue
            if not parked_once:
                # the hold default: the slot is still owned in TOOL_WAIT
                assert s.slot >= 0
                free_before = eng.pool.free_slots
                eng.park_session(s.session_id)
                assert s.slot == -1
                assert eng.pool.free_slots == free_before + 1
                parked_once = True
            eng.resume_session(s.session_id)   # tool done immediately
        if not reactor.pending():
            break
    else:
        raise AssertionError("sessions never finished")
    reactor.drain(max_wall_s=10.0)
    assert eng.hotpath_stats["parks"] == 1
    assert eng.hotpath_stats["unparks"] == 1

    streams = events_by_session(events)
    want = oracle_streams(TINY, params, sessions,
                          num_slots=ecfg.num_slots, max_seq=ecfg.max_seq)
    for s in sessions:
        assert streams[s.session_id] == want[s.session_id]
    assert all(reactor.poll(h) is HandleStatus.DONE for h in handles)
