"""Serving substrate: KV pool invariants (hypothesis), workload Table-I
distributions, metrics, and an end-to-end engine run per policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvcache import KVCachePool
from repro.serving.metrics import SLOThresholds, collect_tpots
from repro.serving.policies import POLICIES
from repro.serving.request import SessionState
from repro.serving.workload import make_workload, table1_statistics

TINY = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test")


# ---------------------------------------------------------------------------
# KV cache pool
# ---------------------------------------------------------------------------

def test_pool_alloc_free_cycle():
    pool = KVCachePool(TINY, 4, 64)
    slots = [pool.alloc() for _ in range(4)]
    assert len(set(slots)) == 4 and pool.free_slots == 0
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.free(slots[0])
    assert pool.alloc() == slots[0]


def test_prefix_snapshot_roundtrip():
    pool = KVCachePool(TINY, 4, 64)
    s = pool.alloc()
    toks = np.arange(10, dtype=np.int32)
    # write something recognisable into the slot
    pool.cache = jax.tree.map(lambda l: l.at[:, s].set(1.0), pool.cache)
    pool.lengths[s] = 10
    pool.register_prefix(s, toks)
    d = pool.alloc()
    entry = pool.lookup(toks)
    assert entry is not None and entry.length == 10
    pool.restore_prefix(d, entry)
    assert pool.lengths[d] == 10
    for leaf in jax.tree_util.tree_leaves(pool.cache):
        np.testing.assert_array_equal(np.asarray(leaf[:, d]),
                                      np.asarray(leaf[:, s]))
    assert pool.lookup(np.arange(11, dtype=np.int32)) is None


def test_prefix_eviction_is_lru():
    """Eviction must be least-recently-used, not min-refs: under
    min-refs an old hot prefix (many hits) can never be displaced and a
    fresh deployment's prompt is thrashed forever."""
    pool = KVCachePool(TINY, 4, 64, max_prefix_entries=2)
    slot = pool.alloc()
    a = np.arange(5, dtype=np.int32)
    b = np.arange(6, dtype=np.int32)
    c = np.arange(7, dtype=np.int32)

    def reg(tokens):
        pool.lengths[slot] = len(tokens)
        pool.register_prefix(slot, tokens)

    reg(a)
    for _ in range(3):                      # a: hot (3 hits) but stale
        assert pool.lookup(a) is not None
    reg(b)                                  # b: fresh, zero hits
    reg(c)                                  # at capacity -> evict LRU (a)
    assert pool.lookup(b) is not None       # fresh prefix survives
    assert pool.lookup(c) is not None
    assert pool.lookup(a) is None           # stale-hot one was evicted
    assert pool.stats["evictions"] == 1


def test_register_prefix_refresh_on_exact_key_hit():
    """Re-registering an already-cached prefix must not re-snapshot or
    evict another entry at capacity — it only refreshes recency (so the
    re-registered prefix is treated as just-used by LRU eviction)."""
    pool = KVCachePool(TINY, 4, 64, max_prefix_entries=2)
    slot = pool.alloc()
    a = np.arange(5, dtype=np.int32)
    b = np.arange(6, dtype=np.int32)
    c = np.arange(7, dtype=np.int32)

    def reg(tokens):
        pool.lengths[slot] = len(tokens)
        pool.register_prefix(slot, tokens)

    reg(a)
    reg(b)                                  # at capacity, no eviction yet
    reg(a)                                  # exact-key hit: refresh only
    assert pool.stats["prefix_refreshes"] == 1
    assert pool.stats["evictions"] == 0     # the old code evicted here
    reg(c)                                  # LRU is now b, not a
    assert pool.lookup(a) is not None
    assert pool.lookup(c) is not None
    assert pool.lookup(b) is None
    assert pool.stats["evictions"] == 1


@given(mask=st.lists(st.booleans(), min_size=4, max_size=4))
@settings(max_examples=20, deadline=None)
def test_commit_mask_protects_inactive(mask):
    """commit() must only update rows where mask is True — the property
    that keeps inactive sessions' SSM states untouched."""
    pool = KVCachePool(TINY, 4, 16)
    old = pool.cache
    new = jax.tree.map(lambda l: l + 1.0, old)
    pool.commit(new, np.asarray(mask))
    for leaf_new, leaf_cur in zip(jax.tree_util.tree_leaves(new),
                                  jax.tree_util.tree_leaves(pool.cache)):
        for b, m in enumerate(mask):
            expect = leaf_new[:, b] if m else leaf_new[:, b] * 0.0
            np.testing.assert_allclose(np.asarray(leaf_cur[:, b]),
                                       np.asarray(expect))


# ---------------------------------------------------------------------------
# workload (Table I)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload,res_rng,dec_rng", [
    ("react", (30, 127), (27, 127)),
    ("plan_execute", (125, 421), (33, 141)),
])
def test_table1_distributions(workload, res_rng, dec_rng):
    stats = table1_statistics(workload, n=100)
    assert 2500 <= stats["cold_prefill"]["min"]
    assert stats["cold_prefill"]["max"] <= 3500 + 3500 // 8
    assert res_rng[0] <= stats["resume_prefill"]["min"]
    assert stats["resume_prefill"]["max"] <= res_rng[1]
    assert dec_rng[0] <= stats["decode"]["min"]
    assert stats["decode"]["max"] <= dec_rng[1]


def test_workload_scaling_and_shared_prefix():
    ws = make_workload(4, vocab_size=128, token_scale=0.25,
                       num_system_prompts=1, seed=3)
    assert all(s.shared_prefix_len > 0 for s in ws)
    a, b = ws[0], ws[1]
    pa = a.turns[0].prefill_tokens[:min(a.shared_prefix_len,
                                        b.shared_prefix_len)]
    pb = b.turns[0].prefill_tokens[:len(pa)]
    np.testing.assert_array_equal(pa, pb)   # shared system prompt


# ---------------------------------------------------------------------------
# engine end-to-end (one per policy)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine_parts():
    params = init_params(TINY, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=4, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05, max_wall_s=60.0)
    return params, ecfg


@pytest.mark.parametrize("policy", ["agentserve", "pd_static", "chunked",
                                    "fcfs"])
def test_engine_end_to_end(tiny_engine_parts, policy):
    params, ecfg = tiny_engine_parts
    sessions = make_workload(3, workload="react", vocab_size=TINY.vocab_size,
                             token_scale=0.0625, num_system_prompts=1,
                             seed=0, stagger_s=0.05)
    eng = ServingEngine(TINY, params, POLICIES[policy], ecfg)
    rep = eng.run(sessions, SLOThresholds(ttft_s=5.0, tpot_s=1.0))
    assert all(s.state == SessionState.FINISHED for s in sessions)
    assert rep.total_output_tokens > 0
    assert rep.throughput_tok_s > 0
    assert np.isfinite(rep.ttft_p50_s) and np.isfinite(rep.tpot_p50_s)
    # every turn produced its full decode burst
    for s in sessions:
        assert s.output_tokens() == sum(t.decode_len for t in s.turns)


def test_agentserve_isolation_invariant(tiny_engine_parts):
    """Cold prefills never enter Q_D (checked via the admission log)."""
    params, ecfg = tiny_engine_parts
    sessions = make_workload(3, vocab_size=TINY.vocab_size,
                             token_scale=0.0625, seed=1)
    eng = ServingEngine(TINY, params, POLICIES["agentserve"], ecfg)
    eng.run(sessions)
    assert eng.slots.stats.rebinds >= 1
    assert eng.slots.stats.misses == 0      # everything pre-established


def test_no_green_pays_on_demand(tiny_engine_parts):
    params, ecfg = tiny_engine_parts
    sessions = make_workload(2, vocab_size=TINY.vocab_size,
                             token_scale=0.0625, seed=2)
    eng = ServingEngine(TINY, params, POLICIES["no_green"], ecfg)
    eng.run(sessions)
    assert eng.slots.stats.misses >= 1      # built inside the serving path
