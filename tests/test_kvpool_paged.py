"""Paged KV pool (DESIGN.md §8): page lifecycle, refcounted prefix
sharing, copy-on-write, park/unpark reference transfer, allocator
exhaustion/fragmentation — plus engine/gateway runs under
``kv_layout="paged"`` asserted token-identical to the slab oracle.

The zero-copy claims are asserted via pool stats: a (page-aligned)
prefix hit and a park/unpark must not increment ``page_copies`` (COW
device copies) — positional data moves by block-table surgery only.
"""
import asyncio
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _serving_util import events_by_session, oracle_streams

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvcache import (KVCachePool, PagedKVCachePool, make_pool)
from repro.serving.policies import POLICIES
from repro.serving.request import SessionState
from repro.serving.workload import make_open_loop_workload, make_workload

GOLDEN = pathlib.Path(__file__).parent / "golden" / "serving_golden.json"

PS = 8                                    # page size for pool unit tests
TINY = ModelConfig(name="tiny-paged", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="test",
                   kv_layout="paged", kv_page_size=PS)
HYBRID = dataclasses.replace(
    TINY, name="tiny-paged-hybrid", family="hybrid",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                  chunk_size=32),
    hybrid_period=2, hybrid_attn_index=0)
# the serving golden trace uses this slab config (tests/test_serving.py)
TINY_SLAB = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=128, tie_embeddings=True, source="test")


def _pool(cfg=TINY, num_slots=4, max_seq=64, **kw) -> PagedKVCachePool:
    return PagedKVCachePool(cfg, num_slots, max_seq, **kw)


def _fill(pool, slot, n, value=1.0):
    """Allocate pages for n tokens and write a recognisable value into
    the slot's positional rows (host-side emulation of a prefill)."""
    pool.prepare_append(slot, int(pool.lengths[slot]), n)
    bt = np.asarray(pool.block_tables_device())
    ps = pool.page_size
    start = int(pool.lengths[slot])
    for pos in range(start, start + n):
        page = bt[slot, pos // ps]
        pool.cache = jax.tree.map(
            lambda l: (l.at[:, page, pos % ps].set(value)
                       if l.shape[1] == pool.num_pages + 1 else l),
            pool.cache)
    pool.lengths[slot] += n


def _slot_rows(pool, slot, n):
    """Gather the first n positional rows of a slot through its table."""
    bt = np.asarray(pool.block_tables_device())[slot]
    out = {}
    for name, layer in pool.cache.items():
        for k, leaf in layer.items():
            if leaf.shape[1] != pool.num_pages + 1:
                continue
            lin = np.asarray(leaf)[:, bt].reshape(
                leaf.shape[0], -1, *leaf.shape[3:])
            out[f"{name}/{k}"] = lin[:, :n].copy()
    return out


# ---------------------------------------------------------------------------
# slot + page lifecycle
# ---------------------------------------------------------------------------

def test_free_rejects_double_free_and_unallocated():
    """Both layouts: free() must be loud for a slot that is not
    currently allocated — the slab pool silently re-added it to _free
    (two sessions could then share a slot; under paging it would also
    corrupt page refcounts)."""
    for pool in (KVCachePool(TINY_SLAB, 4, 64), _pool()):
        s = pool.alloc()
        pool.free(s)
        with pytest.raises(ValueError):
            pool.free(s)                  # double free
        with pytest.raises(ValueError):
            pool.free(3)                  # never allocated
        with pytest.raises(ValueError):
            pool.free(99)                 # out of range


def test_page_alloc_and_free_returns_pages():
    pool = _pool(num_slots=2, max_seq=64)
    assert pool.free_pages == pool.num_pages
    s = pool.alloc()
    _fill(pool, s, 3 * PS)                # 3 pages
    assert pool.free_pages == pool.num_pages - 3
    assert (pool.refcount[np.asarray(pool.block_table[s, :3])] == 1).all()
    pool.free(s)
    assert pool.free_pages == pool.num_pages
    assert (pool.refcount == 0).all()
    assert (pool.block_table[s] == -1).all()


def test_allocator_exhaustion_is_loud():
    cfg = dataclasses.replace(TINY, name="tiny-paged-small")
    pool = PagedKVCachePool(cfg, 2, 64, num_pages=3)
    s = pool.alloc()
    pool.prepare_append(s, 0, 3 * PS)     # takes all 3 pages
    with pytest.raises(RuntimeError):
        pool.prepare_append(s, 3 * PS, 1)


def test_fragmented_free_list_is_reusable():
    """Pages freed out of order must be reallocatable — capacity is
    the page count, not contiguity."""
    pool = _pool(num_slots=4, max_seq=32)
    slots = [pool.alloc() for _ in range(4)]
    for s in slots:
        _fill(pool, s, 2 * PS)
    pool.free(slots[1])
    pool.free(slots[3])                   # free list now interleaved
    s = pool.alloc()
    _fill(pool, s, 4 * PS)                # needs the fragmented pages
    # 3 live slots hold 2+2+4 pages out of 4 slots * 4 pages capacity
    assert pool.free_pages == pool.num_pages - 8
    used = pool.block_table[pool.block_table >= 0]
    assert len(set(used.tolist())) == len(used)   # no page double-booked


# ---------------------------------------------------------------------------
# prefix sharing: refcounts + zero-copy + COW
# ---------------------------------------------------------------------------

def test_prefix_hit_is_zero_copy_and_refcounted():
    pool = _pool()
    s = pool.alloc()
    toks = np.arange(2 * PS, dtype=np.int32)      # page-aligned prefix
    _fill(pool, s, len(toks), value=1.0)
    pool.register_prefix(s, toks)
    shared = pool.block_table[s, :2].copy()
    assert (pool.refcount[shared] == 2).all()     # slot + entry

    d = pool.alloc()
    entry = pool.lookup(toks)
    assert entry is not None and entry.length == len(toks)
    copies_before = pool.stats["page_copies"]
    pool.restore_prefix(d, entry)
    assert pool.stats["page_copies"] == copies_before   # zero device copies
    assert (pool.block_table[d, :2] == shared).all()    # same physical pages
    assert (pool.refcount[shared] == 3).all()
    np.testing.assert_allclose(
        list(_slot_rows(pool, d, len(toks)).values())[0],
        list(_slot_rows(pool, s, len(toks)).values())[0])

    pool.free(d)
    assert (pool.refcount[shared] == 2).all()
    pool.free(s)
    assert (pool.refcount[shared] == 1).all()     # entry still holds them


def test_cow_on_first_divergent_write():
    """Two sessions share prefix pages; the first write past the shared
    boundary must copy-on-write exactly the shared tail page and leave
    the donor's data untouched."""
    pool = _pool()
    s = pool.alloc()
    toks = np.arange(PS + PS // 2, dtype=np.int32)  # unaligned: 1.5 pages
    _fill(pool, s, len(toks), value=1.0)
    pool.register_prefix(s, toks)
    d = pool.alloc()
    pool.restore_prefix(d, pool.lookup(toks))
    tail = int(pool.block_table[d, 1])
    assert tail == int(pool.block_table[s, 1])      # shared before COW

    before = _slot_rows(pool, s, len(toks))
    pool.prepare_append(d, len(toks), 4)            # writes into the tail page
    assert pool.stats["page_copies"] == 1           # exactly one page copied
    assert int(pool.block_table[d, 1]) != tail      # d owns a fresh page
    assert int(pool.block_table[s, 1]) == tail      # donor untouched
    assert int(pool.block_table[d, 0]) == int(pool.block_table[s, 0])
    after = _slot_rows(pool, s, len(toks))
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    # and the COW copy carried the shared rows into the fresh page
    d_rows = _slot_rows(pool, d, len(toks))
    for k in before:
        np.testing.assert_allclose(d_rows[k], before[k])


def test_prefix_eviction_releases_page_refs():
    pool = _pool(max_prefix_entries=1)
    s = pool.alloc()
    a = np.arange(PS, dtype=np.int32)
    _fill(pool, s, PS)
    pool.register_prefix(s, a)
    page_a = int(pool.block_table[s, 0])
    pool.free(s)
    assert pool.refcount[page_a] == 1               # entry's ref survives

    s2 = pool.alloc()
    b = np.arange(PS, 3 * PS, dtype=np.int32)
    _fill(pool, s2, 2 * PS)
    pool.register_prefix(s2, b)                     # capacity 1 -> evict a
    assert pool.stats["evictions"] == 1
    assert pool.refcount[page_a] == 0               # a's pages released
    assert pool.lookup(a) is None


# ---------------------------------------------------------------------------
# park / unpark: reference transfer
# ---------------------------------------------------------------------------

def test_park_unpark_is_zero_copy_reference_transfer():
    pool = _pool()
    s = pool.alloc()
    _fill(pool, s, PS + 3, value=2.0)               # unaligned on purpose
    want = _slot_rows(pool, s, PS + 3)
    pages = pool.block_table[s, :2].copy()

    copies_before = pool.stats["page_copies"]
    entry = pool.park(s)
    assert pool.stats["page_copies"] == copies_before   # no device copy
    assert pool.free_slots == pool.num_slots            # slot returned
    assert (pool.refcount[pages] == 1).all()            # refs transferred
    assert entry.length == PS + 3

    other = pool.alloc()                                # slot reuse is safe
    _fill(pool, other, 2 * PS, value=9.0)

    dst = pool.alloc()
    pool.unpark(dst, entry)
    assert pool.stats["page_copies"] == copies_before
    assert (pool.block_table[dst, :2] == pages).all()   # same pages back
    got = _slot_rows(pool, dst, PS + 3)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    assert pool.stats["parks"] == 1 and pool.stats["unparks"] == 1


def test_park_on_hybrid_snapshots_state_only():
    """Hybrid: park must carry the SSM point summary (a device copy of
    the small state leaves — counted separately) but still move the
    positional pages by reference."""
    pool = _pool(cfg=HYBRID)
    s = pool.alloc()
    pool.prepare_append(s, 0, PS)
    pool.lengths[s] = PS
    pool.cache = jax.tree.map(lambda l: l + 1.0, pool.cache)
    entry = pool.park(s)
    assert entry.state is not None
    assert pool.stats["page_copies"] == 0
    assert pool.stats["state_copies"] == 1
    d = pool.alloc()                       # alloc zeroes slot SSM state
    pool.unpark(d, entry)
    for name, layer in pool.cache.items():
        for k, leaf in layer.items():
            if leaf.shape[1] == pool.num_pages + 1:
                continue
            np.testing.assert_array_equal(np.asarray(leaf[:, d]),
                                          np.ones_like(leaf[:, d]))


def test_make_pool_dispatches_on_layout():
    assert isinstance(make_pool(TINY, 2, 64), PagedKVCachePool)
    assert isinstance(make_pool(TINY_SLAB, 2, 64), KVCachePool)
    assert not isinstance(make_pool(TINY_SLAB, 2, 64), PagedKVCachePool)


# ---------------------------------------------------------------------------
# paged Pallas kernels: block-table index maps (interpret-mode parity)
# ---------------------------------------------------------------------------

def _arena_case(seed=0, ps=32, P_max=8, B=3, Hk=2, hd=32):
    from repro.models.attention import paged_gather
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    num_pages = B * P_max
    k_arena = jax.random.normal(k2, (num_pages + 1, ps, Hk, hd))
    v_arena = jax.random.normal(k3, (num_pages + 1, ps, Hk, hd))
    # shuffled physical pages: parity only holds if the index maps
    # really go through the table
    perm = np.random.default_rng(seed).permutation(num_pages)
    bt = jnp.asarray(perm[:B * P_max].reshape(B, P_max).astype(np.int32))
    return (k1, k_arena, v_arena, bt,
            paged_gather(k_arena, bt), paged_gather(v_arena, bt))


def test_paged_decode_kernel_parity():
    from repro.kernels import ops
    from repro.models.attention import blocked_attention
    k1, ka, va, bt, k_lin, v_lin = _arena_case()
    q = jax.random.normal(k1, (3, 1, 4, 32))
    for lens in ([1, 37, 256], [5, 5, 5], [33, 64, 200]):
        lengths = jnp.asarray(lens, jnp.int32)
        out = ops.flash_decode_paged(q, ka, va, lengths, bt, interpret=True)
        exp = blocked_attention(q, k_lin, v_lin, q_offset=lengths - 1,
                                lengths=lengths, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("window", [0, 48])
def test_paged_prefill_kernel_parity(window):
    from repro.kernels import ops
    from repro.models.attention import blocked_attention
    k1, ka, va, bt, k_lin, v_lin = _arena_case(seed=window)
    Sq = 32
    q = jax.random.normal(k1, (3, Sq, 4, 32))
    qoff = jnp.asarray([8, 0, 200], jnp.int32)
    lens = qoff + Sq
    out = ops.flash_prefill_paged(q, ka, va, qoff, lens, bt, window=window,
                                  interpret=True)
    exp = blocked_attention(q, k_lin, v_lin, q_offset=qoff, lengths=lens,
                            causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


def test_paged_prefill_quant_kernel_parity():
    from repro.kernels import ops
    from repro.models.attention import (blocked_attention_quant,
                                        paged_gather, quantize_kv)
    k1, ka, va, bt, _, _ = _arena_case(seed=7)
    kq, ks = quantize_kv(ka)
    vq, vs = quantize_kv(va)
    Sq = 32
    q = jax.random.normal(k1, (3, Sq, 4, 32))
    qoff = jnp.asarray([8, 0, 200], jnp.int32)
    lens = qoff + Sq
    out = ops.flash_prefill_paged_quant(q, kq, ks, vq, vs, qoff, lens, bt,
                                        interpret=True)
    exp = blocked_attention_quant(
        q, paged_gather(kq, bt), paged_gather(ks, bt),
        paged_gather(vq, bt), paged_gather(vs, bt),
        q_offset=qoff, lengths=lens, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# engine: paged runs are token-identical to the slab path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_paged_params():
    # TINY/HYBRID paged configs share parameter shapes with their slab
    # twins, so one init serves both engines and the oracle
    return init_params(TINY, jax.random.PRNGKey(0))


def _paged_cfg(page_size=32):
    return dataclasses.replace(TINY_SLAB, name=f"tiny-paged-{page_size}",
                               kv_layout="paged", kv_page_size=page_size)


def test_engine_paged_matches_golden_trace(tiny_paged_params):
    """kv_layout='paged' must reproduce the slab engine's golden trace
    token-for-token on the exact same workload/engine config."""
    g = json.loads(GOLDEN.read_text())
    w = g["workload"]
    sessions = make_workload(w["n"], workload=w["workload"],
                             vocab_size=w["vocab_size"],
                             token_scale=w["token_scale"],
                             num_system_prompts=w["num_system_prompts"],
                             seed=w["seed"], stagger_s=w["stagger_s"])
    ecfg = EngineConfig(**g["engine_cfg"], record_events=True)
    eng = ServingEngine(_paged_cfg(), tiny_paged_params,
                        POLICIES["agentserve"], ecfg)
    rep = eng.run(sessions)
    assert rep.total_output_tokens == g["total_output_tokens"]
    for s, gs in zip(sessions, g["per_session"]):
        assert s.output_tokens() == gs["output_tokens"]
        assert int(s.last_token) == gs["final_token"]
    streams = events_by_session(eng.event_log)
    want = oracle_streams(TINY_SLAB, tiny_paged_params, sessions,
                          num_slots=ecfg.num_slots, max_seq=ecfg.max_seq)
    for s in sessions:
        assert streams[s.session_id] == want[s.session_id]
    assert eng.pool.stats["page_allocs"] > 0


def test_engine_paged_prefix_hit_and_aligned_zero_copy(tiny_paged_params):
    """A paged engine run with a page-aligned shared prefix: the prefix
    hit itself is pure table surgery (COW copies may only come from
    later divergent writes, at most one per hit), and streams stay
    oracle-identical."""
    page = 16
    sessions = make_workload(3, workload="react", vocab_size=128,
                             token_scale=0.0625, num_system_prompts=1,
                             seed=3, stagger_s=0.02)
    for s in sessions:                    # align the registered boundary
        s.shared_prefix_len = (s.shared_prefix_len // page) * page
    assert all(s.shared_prefix_len >= page for s in sessions)
    ecfg = EngineConfig(num_slots=4, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05, max_wall_s=60.0,
                        record_events=True)
    eng = ServingEngine(_paged_cfg(page), tiny_paged_params,
                        POLICIES["agentserve"], ecfg)
    eng.run(sessions)
    assert all(s.state == SessionState.FINISHED for s in sessions)
    hits = eng.pool.stats["prefix_hits"]
    assert hits >= 1
    # a hit shares whole pages; divergence costs at most the boundary
    # page — with an aligned boundary the restored pages themselves are
    # never copied, so COW count is bounded by the number of boundary
    # crossings, not by prefix length
    assert eng.pool.stats["page_copies"] <= hits
    streams = events_by_session(eng.event_log)
    want = oracle_streams(TINY_SLAB, tiny_paged_params, sessions,
                          num_slots=ecfg.num_slots, max_seq=ecfg.max_seq)
    for s in sessions:
        assert streams[s.session_id] == want[s.session_id]


def test_engine_paged_hybrid_matches_slab_engine():
    """Hybrid stack under the paged layout: SSM leaves stay per-slot,
    attention pages share — streams must be token-identical to a slab
    engine run of the same workload.

    The comparison is engine-vs-engine under the deterministic
    ``chunked`` policy (fixed chunk sizes): hybrid streams are only
    schedule-independent up to the SSD chunk *boundaries* (float
    grouping), which the adaptive policy varies with wall-clock noise —
    a pre-existing property of the slab engine, not a paged artefact.
    Executable-shape *padding* is already invariant (the SSM pad
    fencing in mamba2.py), which is what makes slab and paged runs of
    the same schedule bit-identical."""
    hybrid_slab = dataclasses.replace(HYBRID, name="tiny-hyb-slab",
                                      kv_layout="slab")
    params = init_params(HYBRID, jax.random.PRNGKey(1))
    ecfg = EngineConfig(num_slots=4, max_seq=256, cycle_budget=40,
                        granularity=8, b_min=8, b_max=32, b_init=16,
                        delta_b=8, control_interval_s=0.05, max_wall_s=90.0,
                        megastep_max=4, resume_batch_max=2,
                        autotune_chunks=False, record_events=True)

    def run(cfg):
        sessions = make_workload(2, vocab_size=HYBRID.vocab_size,
                                 token_scale=0.03, num_system_prompts=1,
                                 seed=5, stagger_s=0.05)
        eng = ServingEngine(cfg, params, POLICIES["chunked"], ecfg)
        eng.run(sessions)
        assert all(s.state == SessionState.FINISHED for s in sessions)
        return sessions, events_by_session(eng.event_log), eng

    _, slab_streams, _ = run(hybrid_slab)
    sessions, paged_streams, eng = run(
        dataclasses.replace(HYBRID, kv_page_size=32))
    for s in sessions:
        assert paged_streams[s.session_id] == slab_streams[s.session_id]
        assert len(paged_streams[s.session_id]) == s.output_tokens()
    assert eng.pool.stats["page_allocs"] > 0


def test_engine_paged_pallas_prefill_token_parity(tiny_paged_params):
    """The paged block-table Pallas prefill kernel must be semantically
    invisible: engine outcomes identical to the paged XLA gather path."""
    ecfg = EngineConfig(num_slots=4, max_seq=256, cycle_budget=48,
                        granularity=8, b_min=8, b_max=64, b_init=16,
                        delta_b=8, control_interval_s=0.05, max_wall_s=120.0)
    outcomes = {}
    for backend in ("xla", "pallas"):
        cfg = dataclasses.replace(_paged_cfg(), name=f"tp-{backend}",
                                  prefill_kernel=backend)
        sessions = make_workload(2, workload="react",
                                 vocab_size=cfg.vocab_size, token_scale=0.04,
                                 num_system_prompts=1, seed=7,
                                 stagger_s=0.05)
        eng = ServingEngine(cfg, tiny_paged_params, POLICIES["agentserve"],
                            ecfg)
        eng.run(sessions)
        assert all(s.state == SessionState.FINISHED for s in sessions)
        outcomes[backend] = [(s.last_token, s.output_tokens(), s.cached_len)
                             for s in sessions]
    assert outcomes["xla"] == outcomes["pallas"]


# ---------------------------------------------------------------------------
# gateway: paged park/unpark bit-exactness under slot pressure
# ---------------------------------------------------------------------------

def _drive_gateway(cfg, params, policy, *, seed=2):
    from repro.serving.gateway import AgentGateway, GatewayConfig, \
        drive_open_loop

    ecfg = EngineConfig(num_slots=2, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05,
                        autotune_chunks=False, max_wall_s=float("inf"))
    eng = ServingEngine(cfg, params, POLICIES[policy], ecfg)
    gw = AgentGateway(eng, GatewayConfig(high_watermark=64,
                                         tool_policy="release"))
    sessions = make_open_loop_workload(3, workload="react",
                                       vocab_size=cfg.vocab_size,
                                       token_scale=0.0625, seed=seed,
                                       rate_rps=1000.0)

    async def go():
        await gw.start()
        run = await drive_open_loop(gw, sessions,
                                    [s.ready_s for s in sessions])
        await gw.stop(timeout_s=120.0)
        return run

    return asyncio.run(go()), eng, gw, sessions


def test_gateway_paged_release_park_unpark_token_exact_dense():
    """release policy with more live agents than KV slots under the
    paged layout: parks happen (reference transfer, zero positional
    copies) and every resumed stream is token-exact vs the slab
    oracle."""
    params = init_params(TINY, jax.random.PRNGKey(0))
    run, eng, gw, sessions = _drive_gateway(_paged_cfg(), params,
                                            "agentserve")
    assert len(run.completed) == 3
    assert gw.counters["parked"] >= 1
    assert eng.hotpath_stats["unparks"] == eng.hotpath_stats["parks"] >= 1
    # positional data is never copied for park/unpark: every page copy
    # must be prefix-boundary COW — at most one per registration (the
    # donor diverging past an unaligned shared tail page) plus one per
    # hit (the restorer diverging)
    assert (eng.pool.stats["page_copies"]
            <= eng.pool.stats["prefix_misses"]
            + eng.pool.stats["prefix_hits"])
    streams = events_by_session([ev for _, ev in run.events])
    want = oracle_streams(TINY_SLAB, params, sessions,
                          num_slots=2, max_seq=512)
    for s in run.completed:
        assert streams[s.session_id] == want[s.session_id]


def test_gateway_paged_release_park_unpark_token_exact_hybrid():
    """Hybrid gateway under slot pressure: paged park/unpark (page
    reference transfer + SSM point snapshot) must reproduce the slab
    gateway's streams token-for-token (engine-vs-engine under the
    deterministic ``chunked`` policy — see the hybrid engine test for
    why the oracle is not the reference here)."""
    params = init_params(HYBRID, jax.random.PRNGKey(1))
    slab = dataclasses.replace(HYBRID, name="tiny-hyb-slab2",
                               kv_layout="slab")
    run_s, eng_s, _, _ = _drive_gateway(slab, params, "chunked")
    run_p, eng_p, gw_p, _ = _drive_gateway(
        dataclasses.replace(HYBRID, kv_page_size=32), params, "chunked")
    assert len(run_s.completed) == len(run_p.completed) == 3
    assert gw_p.counters["parked"] >= 1
    assert eng_p.hotpath_stats["unparks"] == eng_p.hotpath_stats["parks"] >= 1
    assert (eng_p.pool.stats["page_copies"]
            <= eng_p.pool.stats["prefix_misses"]
            + eng_p.pool.stats["prefix_hits"])
    slab_streams = events_by_session([ev for _, ev in run_s.events])
    paged_streams = events_by_session([ev for _, ev in run_p.events])
    for sid in slab_streams:
        assert paged_streams[sid] == slab_streams[sid]
