"""Soft-dependency shim for hypothesis.

Property tests use hypothesis when it is installed (it is listed in
``requirements-dev.txt``); when it is missing, only those tests are
skipped instead of the whole module failing at collection (the seed
failure mode: a hard ``import hypothesis`` at module top took every
test in the file down with it).
"""
try:
    import hypothesis.strategies as st                      # noqa: F401
    from hypothesis import given, settings                  # noqa: F401
except ModuleNotFoundError:      # pragma: no cover - CI installs hypothesis
    import pytest

    class _StrategyStub:
        """Accepts any ``st.<name>(...)`` call at collection time."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -r "
                   "requirements-dev.txt)")

    def settings(*_args, **_kwargs):
        return lambda fn: fn
