"""Training substrate: loss decreases, chunked CE == full CE, microbatch
gradient accumulation == full-batch gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.training.optimizer import (AdamWConfig, apply_updates,
                                      init_opt_state, schedule)
from repro.training.train_step import lm_loss, make_train_step

KEY = jax.random.PRNGKey(0)


def test_loss_decreases(tiny_cfg, tiny_params):
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=100,
                          weight_decay=0.0)
    step = jax.jit(make_train_step(tiny_cfg, opt_cfg, moe_mode="dense"))
    opt = init_opt_state(opt_cfg, tiny_params)
    toks = jax.random.randint(KEY, (4, 32), 0, tiny_cfg.vocab_size)
    params = tiny_params
    losses = []
    for _ in range(8):
        params, opt, stats = step(params, opt, {"tokens": toks})
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_chunked_ce_matches_full(tiny_cfg, tiny_params):
    toks = jax.random.randint(KEY, (2, 32), 0, tiny_cfg.vocab_size)
    full, _ = lm_loss(tiny_params, tiny_cfg, toks, moe_mode="dense",
                      remat=False)
    chunked, _ = lm_loss(tiny_params, tiny_cfg, toks, moe_mode="dense",
                         remat=False, ce_chunk=8)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_chunked_ce_grads_match(tiny_cfg, tiny_params):
    toks = jax.random.randint(KEY, (2, 32), 0, tiny_cfg.vocab_size)
    g1 = jax.grad(lambda p: lm_loss(p, tiny_cfg, toks, moe_mode="dense",
                                    remat=False)[0])(tiny_params)
    g2 = jax.grad(lambda p: lm_loss(p, tiny_cfg, toks, moe_mode="dense",
                                    remat=False, ce_chunk=8)[0])(tiny_params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_microbatch_matches_full_batch(tiny_cfg, tiny_params):
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    toks = jax.random.randint(KEY, (4, 16), 0, tiny_cfg.vocab_size)
    s1 = make_train_step(tiny_cfg, opt_cfg, moe_mode="dense")
    s2 = make_train_step(tiny_cfg, opt_cfg, moe_mode="dense", microbatches=2)
    o1 = init_opt_state(opt_cfg, tiny_params)
    p1, _, st1 = s1(tiny_params, o1, {"tokens": toks})
    o2 = init_opt_state(opt_cfg, tiny_params)
    p2, _, st2 = s2(tiny_params, o2, {"tokens": toks})
    np.testing.assert_allclose(float(st1["loss"]), float(st2["loss"]),
                               rtol=1e-5)
    # AdamW's rsqrt amplifies f32 summation-order noise in the grads;
    # compare post-update params with a correspondingly loose tolerance
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, 0)) == 0.0
    assert float(schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(schedule(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    assert float(schedule(cfg, 55)) < float(schedule(cfg, 11))


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=1e-2, grad_clip=0.001, warmup_steps=0,
                      total_steps=10, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    state = init_opt_state(cfg, params)
    new, _, stats = apply_updates(cfg, params, grads, state)
    assert float(stats["grad_norm"]) > 1e5
    # the applied update magnitude is bounded by lr * O(1) post-clip
    assert np.all(np.abs(np.asarray(new["w"] - params["w"])) < 1.0)


@pytest.mark.parametrize("arch", ["olmoe-1b-7b"])
def test_moe_aux_loss_in_training(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    loss, parts = lm_loss(params, cfg, toks, moe_mode="dense", remat=False)
    assert float(parts["aux"]) > 0.0
    assert float(loss) > float(parts["ce"]) - 1e-6
