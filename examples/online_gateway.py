"""Online gateway quickstart: stream tokens from concurrent live agents.

    PYTHONPATH=src python examples/online_gateway.py

Boots the asyncio gateway (DESIGN.md §6) on a tiny CPU model and
submits a handful of agent sessions at open-loop Poisson arrivals.
Each agent's tokens stream back as they are decoded — interleaved
across sessions — with tool waits run on the gateway's clock; one
deliberately tiny watermark run at the end shows a 429 rejection.
"""
import asyncio

import jax

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.gateway import AgentGateway, GatewayConfig, Rejected
from repro.serving.metrics import SLOThresholds, build_open_loop_report
from repro.serving.policies import POLICIES
from repro.serving.workload import make_open_loop_workload

RATE_RPS = 4.0
AGENTS = 5


async def run_agent(gateway, session):
    res = await gateway.submit(session)
    if isinstance(res, Rejected):
        print(f"agent {session.session_id}: shed with {res.status} "
              f"(occupancy {res.occupancy})")
        return None
    toks = []
    async for ev in res.events():
        toks.append(ev.token)
        if ev.first:
            print(f"agent {res.session_id} turn {ev.turn_idx}: "
                  f"first token at t={ev.t:.2f}s")
    print(f"agent {res.session_id}: done, {len(toks)} tokens")
    return res.session


async def main():
    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=6, max_seq=512, cycle_budget=160,
                        granularity=16, control_interval_s=0.1,
                        max_wall_s=float("inf"))
    engine = ServingEngine(cfg, params, POLICIES["agentserve"], ecfg)
    gateway = AgentGateway(engine, GatewayConfig(high_watermark=16))
    await gateway.start()

    sessions = make_open_loop_workload(
        AGENTS, workload="react", vocab_size=cfg.vocab_size,
        token_scale=0.05, seed=0, rate_rps=RATE_RPS)

    async def delayed(sess):
        await asyncio.sleep(sess.ready_s)
        return await run_agent(gateway, sess)

    t0 = asyncio.get_running_loop().time()
    done = await asyncio.gather(*(delayed(s) for s in sessions))
    wall = asyncio.get_running_loop().time() - t0
    await gateway.stop(timeout_s=60.0)

    completed = [s for s in done if s is not None]
    rep = build_open_loop_report(
        "agentserve", completed, wall, RATE_RPS,
        rejected=AGENTS - len(completed),
        thresholds=SLOThresholds(ttft_s=10.0, tpot_s=2.0))
    print(f"\ngoodput {rep.goodput_tok_s:.1f} tok/s, "
          f"TTFT p95 {rep.ttft_p95_s * 1e3:.0f} ms, "
          f"queue delay p95 {rep.queue_delay_p95_s * 1e3:.1f} ms, "
          f"SLO {rep.slo_attainment:.0%}")


if __name__ == "__main__":
    asyncio.run(main())
