"""Multi-agent serving comparison: AgentServe vs the paper's baselines
on the same workload (the Fig-5 experiment, interactively).

    PYTHONPATH=src python examples/multi_agent_serving.py [--agents 4]
"""
import argparse

import jax

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import ServingReport, SLOThresholds
from repro.serving.policies import POLICIES
from repro.serving.workload import make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--workload", default="react")
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2.5-3b")  # one of the paper's own models
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=args.agents + 2, max_seq=768,
                        cycle_budget=160, granularity=16,
                        control_interval_s=0.1, tpot_slo_ms=30.0)

    print(f"# {args.agents} concurrent {args.workload} agents, "
          f"model {cfg.name}")
    print(ServingReport.HEADER)
    for policy in ("agentserve", "pd_static", "chunked", "fcfs"):
        sessions = make_workload(args.agents, workload=args.workload,
                                 vocab_size=cfg.vocab_size,
                                 token_scale=0.125, seed=1)
        eng = ServingEngine(cfg, params, POLICIES[policy], ecfg)
        rep = eng.run(sessions, SLOThresholds(ttft_s=2.0, tpot_s=0.05))
        print(rep.row(), flush=True)


if __name__ == "__main__":
    main()
