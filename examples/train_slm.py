"""End-to-end training driver: train a ~25M-param SLM for a few hundred
steps on the synthetic corpus, checkpoint, and resume.

    PYTHONPATH=src python examples/train_slm.py [--steps 300]

(The contract's "train a ~100M model for a few hundred steps" driver —
scaled to the CI budget by default; pass --d-model 768 --layers 12 for
the full ~100M run.)"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.models.common import count_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_slm.npz")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="slm-example", family="dense", num_layers=args.layers,
        d_model=args.d_model, num_heads=max(args.d_model // 64, 2),
        num_kv_heads=max(args.d_model // 128, 1),
        d_ff=4 * args.d_model, vocab_size=8192, tie_embeddings=True,
        source="examples/train_slm.py")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {count_params(params) / 1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    opt = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=True,
                                      ce_chunk=64))
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      batch_size=args.batch)).batches()
    first = last = None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, stats = step_fn(params, opt, batch)
        loss = float(stats["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 25 == 0:
            print(f"step {step:4d}  loss {loss:.4f}", flush=True)
    print(f"loss: {first:.3f} -> {last:.3f}")
    save_checkpoint(args.ckpt, params, opt, args.steps,
                    meta={"arch": cfg.name})
    # resume round-trip check
    p2, o2, s2 = load_checkpoint(args.ckpt, params, opt)
    print(f"checkpoint round-trip ok (step {s2})")


if __name__ == "__main__":
    main()
