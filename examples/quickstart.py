"""Quickstart: serve three concurrent tool-using agents with AgentServe.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end: config -> model -> engine -> workload
-> report, and prints what the TPOT-driven controller did."""
import jax

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import ServingReport
from repro.serving.policies import POLICIES
from repro.serving.workload import make_workload


def main():
    # 1. pick an architecture (any of the 10 assigned ids work here)
    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))

    # 2. boot the engine: slots are pre-established (Green-Context
    #    analogue) — watch the warmup vs rebind economics below
    engine = ServingEngine(
        cfg, params, POLICIES["agentserve"],
        EngineConfig(num_slots=6, max_seq=768, cycle_budget=160,
                     granularity=16, control_interval_s=0.1))

    # 3. three concurrent ReAct agents sharing one system prompt
    sessions = make_workload(3, workload="react",
                             vocab_size=cfg.vocab_size,
                             token_scale=0.125, num_system_prompts=1)

    # 4. serve and report
    report = engine.run(sessions)
    print(ServingReport.HEADER)
    print(report.row())
    print(f"slot rebinds: {int(report.extra['rebinds'])} "
          f"(mean {report.extra['mean_rebind_us']:.1f} us each; "
          f"pre-establish cost was "
          f"{sum(engine.slots.stats.warmup_s.values()):.2f} s)")
    print(f"prefix-cache hits: {int(report.extra['prefix_hits'])}")
    hist = engine.scheduler.history
    if hist:
        print(f"controller: B_prefill {hist[0].b_prefill} -> "
              f"{hist[-1].b_prefill} tokens; R_min {hist[0].r_min} -> "
              f"{hist[-1].r_min} of {engine.ecfg.cycle_budget}")


if __name__ == "__main__":
    main()
