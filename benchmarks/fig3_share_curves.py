"""Fig 3 reproduction: normalized throughput versus resource share for
decode / cold prefill / resume prefill.

Resource axis: the decode share of the engine cycle token budget
(DESIGN.md §2 — the TPU/CPU analogue of an SM share).  The paper's
qualitative claim to reproduce: decode throughput rises quickly at low
shares and saturates earlier than the prefill curves."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_MODEL, bench_params, engine_config
from repro.serving.profiler import profile_throughput


def run():
    prof = profile_throughput(BENCH_MODEL, bench_params(),
                              ecfg=engine_config(), reps=5)
    return prof


def saturation_knee(curve: np.ndarray, levels: np.ndarray) -> float:
    """Smallest share reaching 90% of the curve's maximum."""
    target = 0.9 * curve[-1]
    idx = int(np.argmax(curve >= target))
    return float(levels[idx])


def main():
    prof = run()
    n = prof.levels / prof.levels[-1]
    print("fig3: share,mu_decode_norm,mu_cold_norm,mu_resume_norm")
    for i in range(len(prof.levels)):
        print(f"fig3,{n[i]:.2f},{prof.mu_decode[i]/prof.mu_decode[-1]:.3f},"
              f"{prof.mu_cold[i]/prof.mu_cold[-1]:.3f},"
              f"{prof.mu_resume[i]/prof.mu_resume[-1]:.3f}")
    kd = saturation_knee(prof.mu_decode, prof.levels)
    kc = saturation_knee(prof.mu_cold, prof.levels)
    print(f"fig3,knee_decode,{kd},knee_cold,{kc},"
          f"decode_saturates_earlier,{kd <= kc}")
    return prof


if __name__ == "__main__":
    main()
