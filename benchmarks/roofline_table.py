"""§Roofline: the three-term roofline per (arch x shape x mesh), read
from the dry-run artifacts in experiments/dryrun/."""
from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import Roofline
from repro.configs.base import ASSIGNED_ARCHS, INPUT_SHAPES, get_config

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_rooflines(mesh: str = "pod16x16"):
    rows = []
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            p = DRYRUN_DIR / f"{arch}_{shape}_{mesh}.json"
            if not p.exists():
                cfg = get_config(arch)
                if not cfg.supports_shape(shape):
                    rows.append((arch, shape, "SKIP",
                                 "encoder-only: no decode phase"))
                continue
            d = json.loads(p.read_text())
            if not d["ok"]:
                rows.append((arch, shape, "FAIL", d["error"][:80]))
                continue
            r = Roofline(arch=arch, shape=shape, mesh=mesh,
                         chips=d["chips"], hlo_flops=d["flops"],
                         hlo_bytes=d["bytes_accessed"],
                         collective_bytes=d["collective_bytes"] / d["chips"],
                         model_flops=d["model_flops"])
            mem_gb = (d.get("memory") or {}).get(
                "total_per_device_bytes", 0) / 1e9
            rows.append((arch, shape, r, mem_gb))
    return rows


def main(mesh: str = "pod16x16"):
    print("roofline: " + Roofline.HEADER + ",mem_gb_per_device")
    for row in load_rooflines(mesh):
        if isinstance(row[2], str):
            print(f"roofline,{row[0]},{row[1]},{row[2]},{row[3]}")
        else:
            print(f"roofline,{row[2].row()},{row[3]:.2f}")
    return 0


if __name__ == "__main__":
    main()
