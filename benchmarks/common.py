"""Shared benchmark scaffolding.

Model: the SmolLM-family reduced config (the paper's own deployment
class is an SLM on a consumer device; our CPU plays the consumer
device).  Engine + workload scales are fixed here so every figure uses
identical conditions.

SLO calibration follows §IV-A: thresholds are the *isolated* (single
session, unloaded) TTFT/TPOT of the model-device pair scaled by a
constant factor.
"""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import SLOThresholds
from repro.serving.policies import POLICIES
from repro.serving.workload import make_workload

BENCH_MODEL = ModelConfig(
    name="smollm-bench", family="dense", num_layers=2, d_model=192,
    num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
    tie_embeddings=True, source="bench")

TOKEN_SCALE = 0.125          # Table-I lengths / 8 for CPU wall-clock
SLO_FACTOR = 3.0             # paper: constant factor over isolated perf


def engine_config(**kw) -> EngineConfig:
    base = dict(num_slots=8, max_seq=768, cycle_budget=160, granularity=16,
                b_min=16, b_max=256, b_init=64, delta_b=16,
                control_interval_s=0.1, tpot_slo_ms=30.0, max_wall_s=240.0)
    base.update(kw)
    return EngineConfig(**base)


@functools.lru_cache(maxsize=1)
def bench_params():
    return init_params(BENCH_MODEL, jax.random.PRNGKey(0))


def make_engine(policy: str, **ecfg_kw) -> ServingEngine:
    return ServingEngine(BENCH_MODEL, bench_params(), POLICIES[policy],
                         engine_config(**ecfg_kw))


@functools.lru_cache(maxsize=1)
def calibrated_thresholds() -> SLOThresholds:
    """Isolated performance: one session, no contention (§IV-A).

    TTFT calibrates against the isolated p95 (the cold prefill is the
    slowest legitimate request even unloaded); TPOT against the isolated
    p50 (steady-state inter-token pace)."""
    eng = make_engine("agentserve")
    sessions = make_workload(1, vocab_size=BENCH_MODEL.vocab_size,
                             token_scale=TOKEN_SCALE, seed=123)
    rep = eng.run(sessions)
    thr = SLOThresholds.from_isolated(rep.ttft_p95_s, rep.tpot_p50_s,
                                      factor=SLO_FACTOR)
    return thr


def sessions_for(n: int, workload: str = "react", seed: int = 0):
    return make_workload(n, workload=workload,
                         vocab_size=BENCH_MODEL.vocab_size,
                         token_scale=TOKEN_SCALE, num_system_prompts=1,
                         seed=seed, stagger_s=0.1)


def timed_csv_row(name: str, fn, derived: str = "") -> str:
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    return f"{name},{us:.0f},{derived or out}"
