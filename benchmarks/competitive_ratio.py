"""§III-B reproduction: empirical validation of the competitive-ratio
bound (Theorem 1 / Corollary 2) over the *measured* throughput profile.

Protocol: profile μ_D/μ_C/μ_R on the real engine substrate (Fig 3),
derive r_min/R*_g from the decode SLO, run the AgentServe controller in
the spatial simulator, and compare its (backlogged) prefill service
against the offline optimum π*."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_MODEL, bench_params, engine_config
from repro.core import competitive as comp
from repro.serving.profiler import profile_throughput
from repro.serving.simulator import simulate, sessions_from_workload
from repro.serving.workload import make_workload


def run(tpot_slo_factor: float = 1.5, eps_bar: float = 0.02):
    prof = profile_throughput(BENCH_MODEL, bench_params(),
                              ecfg=engine_config(), reps=3)
    # an SLO feasible at full allocation (Eq. 5), demanding ~2/3 of peak
    slo_ms = 1000.0 / prof.mu_decode[0] * tpot_slo_factor
    g = float(prof.levels[1] - prof.levels[0])
    rg = comp.r_star_g(prof, comp.r_min_from_slo(slo_ms))

    ws = make_workload(8, vocab_size=BENCH_MODEL.vocab_size,
                       token_scale=0.5, seed=2, stagger_s=0.02)
    res = simulate(prof, sessions_from_workload(ws), planner="agentserve",
                   tpot_slo_ms=slo_ms, eps_ctx=eps_bar)
    eta_bar = float(np.mean(res.eta_trace)) if res.eta_trace else 0.5
    achieved = comp.achieved_service(
        prof, res.eta_trace, res.r_alloc_trace,
        [eps_bar] * len(res.eta_trace))
    optimum = comp.offline_optimum(prof, res.eta_trace, slo_ms)
    rho = achieved / max(optimum, 1e-9)
    delta = max(max(res.r_alloc_trace) - rg, 0.0) if res.r_alloc_trace else g
    b1 = comp.instantaneous_bound(prof, eta=eta_bar, tpot_slo_ms=slo_ms,
                                  delta=delta, eps_bar=eps_bar)
    b2 = comp.linearized_bound(prof, eta=eta_bar, tpot_slo_ms=slo_ms,
                               delta=delta, eps_bar=eps_bar)
    return dict(slo_ms=slo_ms, r_star_g=rg, delta=delta, eta=eta_bar,
                rho_measured=rho, theorem1_bound=b1, corollary2_bound=b2,
                bound_holds=rho >= min(b1, b2) - 1e-6)


def main():
    r = run()
    print("competitive: " + ",".join(r.keys()))
    print("competitive," + ",".join(
        f"{v:.4f}" if isinstance(v, float) else str(v) for v in r.values()))
    assert r["bound_holds"], r
    return r


if __name__ == "__main__":
    main()
