"""Fig 2 reproduction: TPOT spikes when cold prefills overlap decodes.

The paper shows sharp TPOT spikes under naive mixed execution (their
Fig 2 uses an unmodified engine).  We run the same concurrent-agent
workload under the head-of-line-blocking baseline (fcfs == llama.cpp
semantics) and under AgentServe, and report the spike structure:
max/median TPOT ratio and the count of >3x-median spikes."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_engine, sessions_for
from repro.serving.metrics import collect_tpots


def run(concurrency: int = 3, seed: int = 0):
    rows = []
    for policy in ("fcfs", "agentserve"):
        eng = make_engine(policy)
        sessions = sessions_for(concurrency, seed=seed)
        eng.run(sessions)
        tpots = np.asarray(collect_tpots(sessions))
        med = np.median(tpots)
        spikes = int((tpots > 3 * med).sum())
        rows.append(dict(policy=policy, tpot_med_ms=1e3 * med,
                         tpot_max_ms=1e3 * tpots.max(),
                         spike_ratio=float(tpots.max() / med),
                         n_spikes_gt3x=spikes, n_tokens=len(tpots)))
    return rows


def main():
    print("fig2: policy,tpot_med_ms,tpot_max_ms,spike_ratio,n_spikes_gt3x,n")
    for r in run():
        print(f"fig2,{r['policy']},{r['tpot_med_ms']:.2f},"
              f"{r['tpot_max_ms']:.2f},{r['spike_ratio']:.2f},"
              f"{r['n_spikes_gt3x']},{r['n_tokens']}")
    return 0


if __name__ == "__main__":
    main()
