"""Chaos sweep: goodput and recovery latency vs injected fault rate.

    PYTHONPATH=src python benchmarks/chaos.py [--rates 0,0.15,0.3] [--smoke]

Boots the online gateway over the *paged* engine and drives a seeded
open-loop cohort while a deterministic ``FaultPlan`` (DESIGN.md §10)
injects tool errors, tool hangs, engine step faults, client disconnects
and page-exhaustion bursts at the given per-session rate.  The rate-0
run is the fault-free baseline; every faulted run is then held to the
fault-isolation contract:

  * nothing wedges — every submitted stream reaches a terminal state;
  * sessions the plan did NOT fault stream token-identically to the
    baseline (greedy decoding is scheduling-independent, so fault
    handling must not perturb anyone else's tokens);
  * the pool reclaims every slot, and no page is held outside the
    prefix cache (refcount consistency).

Emits ``BENCH_chaos.json`` with one row per fault rate: goodput,
abort/shed counts with per-reason attribution, and disconnect recovery
latency (cancel -> stream terminal) percentiles.  ``--smoke`` is the CI
chaos job: a small cohort at two rates with the same assertions.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import FaultPlan, drive_chaos
from repro.serving.gateway import AgentGateway, GatewayConfig
from repro.serving.metrics import collect_abort_reasons
from repro.serving.policies import PLANNERS
from repro.serving.workload import make_open_loop_workload


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else 0.0


def run_rate(cfg, params, args, fault_rate: float) -> dict:
    """One fault-rate point: fresh engine + gateway + plan (the plan
    carries per-run injection state), seeded identically across rates
    so the workload and arrivals never vary."""
    ecfg = EngineConfig(num_slots=args.slots, max_seq=512,
                        cycle_budget=160, granularity=16,
                        control_interval_s=0.1,
                        max_wall_s=float("inf"))
    engine = ServingEngine(cfg, params, PLANNERS[args.policy], ecfg)
    plan = FaultPlan.generate(
        args.seed, args.agents,
        tool_error_rate=fault_rate,
        tool_hang_rate=fault_rate / 2,
        step_error_rate=fault_rate / 2,
        disconnect_rate=fault_rate / 2,
        page_fault_bursts=1 if fault_rate > 0 else 0)
    gateway = AgentGateway(engine, GatewayConfig(
        high_watermark=max(args.agents * 2, 16),
        tool_timeout_s=0.5, tool_retries=1, tool_backoff_base_s=0.01,
        tool_failure_policy="abort"), faults=plan)
    sessions = make_open_loop_workload(
        args.agents, workload=args.workload, vocab_size=cfg.vocab_size,
        token_scale=args.token_scale, num_system_prompts=1,
        seed=args.seed, rate_rps=args.rate_rps)
    arrivals = [s.ready_s for s in sessions]

    async def go():
        await gateway.start()
        run = await asyncio.wait_for(
            drive_chaos(gateway, sessions, arrivals, plan),
            timeout=args.max_wall)
        await gateway.stop(timeout_s=args.max_wall)
        return run

    run = asyncio.run(go())
    # arrival offsets are strictly increasing, so gateway session ids
    # line up with the plan's per-index fault targets
    assert [s.session_id for s in sessions] == list(range(args.agents)), \
        "session-id/plan mapping drifted"
    assert run.wedged() == 0, "a stream reached no terminal state"

    pool = engine.pool
    assert pool.free_slots == ecfg.num_slots, "leaked KV slot"
    prefix_refs = sum(len(e.pages) for e in pool._prefix.values())
    assert int(pool.refcount.sum()) == prefix_refs, "leaked page refs"
    # telemetry invariant (DESIGN.md §11): every terminal — DONE,
    # tool_failed, disconnected, kv_exhausted, step faults — must have
    # closed its session and slot spans; a faulted run may leak none
    tracer = engine.telemetry.tracer
    assert tracer is not None and tracer.open_span_count() == 0, \
        f"leaked spans after faulted run: {tracer.open_spans()}"

    tokens = sum(len(v) for v in run.streams().values())
    good_tokens = sum(len(run.streams().get(s.session_id, []))
                      for s in run.completed)
    wall = max(run.wall_s, 1e-9)
    return {
        "fault_rate": fault_rate,
        "submitted": args.agents,
        "completed": len(run.completed),
        "aborted": len(run.aborted),
        "rejected": len(run.rejected),
        "wall_s": run.wall_s,
        "tokens": tokens,
        "goodput_tok_s": good_tokens / wall,
        "throughput_tok_s": tokens / wall,
        "abort_reasons": collect_abort_reasons(run.aborted),
        "injected": dict(plan.injected),
        "recovery_p50_ms": _pct(run.recovery_s, 50) * 1e3,
        "recovery_p95_ms": _pct(run.recovery_s, 95) * 1e3,
        "terminal_faulted": sorted(plan.faulted_sessions()),
        "gateway": gateway.stats(),
        "streams": {str(k): v for k, v in run.streams().items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="0,0.15,0.3",
                    help="comma-separated per-session fault rates")
    ap.add_argument("--agents", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--policy", default="agentserve",
                    choices=sorted(PLANNERS))
    ap.add_argument("--workload", default="react",
                    choices=["react", "plan_execute"])
    ap.add_argument("--token-scale", type=float, default=0.0625)
    ap.add_argument("--rate-rps", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-wall", type=float, default=180.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI chaos smoke: 8 agents, 2 rates, bounded "
                         "wall clock, full isolation assertions")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()

    if args.smoke:
        args.agents, args.token_scale = 8, 0.04
        args.rates = "0,0.3"

    cfg = get_smoke_config("smollm-360m")
    cfg = dataclasses.replace(cfg, kv_layout="paged", kv_page_size=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rates = [float(r) for r in args.rates.split(",")]
    if rates[0] != 0.0:
        rates.insert(0, 0.0)             # the baseline is not optional

    print(f"model={cfg.name} backend={jax.default_backend()} "
          f"agents={args.agents} fault_rates={rates}")
    results = []
    baseline_streams = None
    for rate in rates:
        res = run_rate(cfg, params, args, rate)
        if rate == 0.0:
            assert res["aborted"] == 0 and res["completed"] == args.agents
            baseline_streams = res["streams"]
        else:
            # the isolation contract: every session the plan did not
            # terminally fault streams token-identical to the baseline
            faulted = set(res["terminal_faulted"])
            diverged = [sid for sid in range(args.agents)
                        if sid not in faulted
                        and res["streams"].get(str(sid))
                        != baseline_streams.get(str(sid))]
            res["unfaulted_identical"] = not diverged
            assert not diverged, \
                f"unfaulted sessions diverged under faults: {diverged}"
        row = {k: v for k, v in res.items() if k != "streams"}
        results.append(row)
        print(f"rate={rate:<5} completed={res['completed']:>3} "
              f"aborted={res['aborted']:>3} "
              f"goodput={res['goodput_tok_s']:.1f} tok/s "
              f"reasons={res['abort_reasons']} "
              f"recovery_p95={res['recovery_p95_ms']:.0f}ms", flush=True)

    report = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "agents": args.agents,
        "slots": args.slots,
        "workload": args.workload,
        "token_scale": args.token_scale,
        "seed": args.seed,
        "rates": results,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if args.smoke:
        faulted_rows = [r for r in results if r["fault_rate"] > 0]
        assert faulted_rows and all(r["aborted"] > 0 or r["injected"][
            "page_exhaustion"] > 0 or not r["terminal_faulted"]
            for r in faulted_rows), "smoke run injected nothing"
        assert all(r.get("unfaulted_identical", True) for r in results)


if __name__ == "__main__":
    main()
