"""Benchmark harness — one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
Prints ``name,...`` CSV rows per benchmark (contract format).
"""
from __future__ import annotations

import argparse
import sys
import time


BENCHES = ["table1", "fig3", "fig2", "fig7", "fig5", "fig6",
           "competitive", "roofline"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced concurrency sweep")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    t0 = time.time()
    if "table1" in only:
        from benchmarks import table1_tokens
        table1_tokens.main()
    if "fig3" in only:
        from benchmarks import fig3_share_curves
        fig3_share_curves.main()
    if "fig2" in only:
        from benchmarks import fig2_tpot_spikes
        fig2_tpot_spikes.main()
    if "fig7" in only:
        from benchmarks import fig7_ablation
        fig7_ablation.main()
    if "fig5" in only:
        from benchmarks import fig5_serving
        fig5_serving.main(quick=args.quick)
    if "fig6" in only:
        from benchmarks import fig6_slo
        fig6_slo.main(quick=args.quick)
    if "competitive" in only:
        from benchmarks import competitive_ratio
        competitive_ratio.main()
    if "roofline" in only:
        from benchmarks import roofline_table
        roofline_table.main()
    print(f"benchmarks complete in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
