"""Fig 7 reproduction: ablation at N=4-6 concurrent agents, p95 tails.

The sweep is driven through the **planner registry** (DESIGN.md §9):
every registered policy — the paper's comparison set plus the
SLO-class ``priority`` planner — runs on identical engine machinery,
and each row carries its plan-journal summary (cycles, preemptions,
mean scheduled chunk) so scheduling behaviour is attributable per
policy, not inferred from tails alone.

  No-Alg   — Algorithm 1 disabled: static partition at 20/50/80% decode
             reservation (the paper fixes one static point; we sweep to
             show what adaptation buys — matching the best static point
             without knowing it, vs degradation at mis-tuned points).
  No-Green — no pre-established slots: executables constructed on demand
             inside the serving path.

Also reports the slot-economics table (construction vs rebind cost) that
backs the paper's <50 us rebinding claim on our substrate."""
from __future__ import annotations

import dataclasses

from benchmarks.common import calibrated_thresholds, sessions_for
from repro.serving.engine import ServingEngine
from repro.serving.policies import NO_ALG, PLANNERS, make_planner


def variants():
    """(name, planner) pairs: the registry plus the static-partition
    sweep derived from No-Alg."""
    out = [(name, make_planner(spec)) for name, spec in PLANNERS.items()]
    for frac in (0.2, 0.5, 0.8):
        out.append((f"no_alg_static{int(frac * 100)}",
                    make_planner(dataclasses.replace(
                        NO_ALG, static_r_frac=frac))))
    return out


def run(concurrency: int = 4, seed: int = 0):
    from benchmarks.common import BENCH_MODEL, bench_params, engine_config
    thr = calibrated_thresholds()
    rows = []
    for name, planner in variants():
        eng = ServingEngine(BENCH_MODEL, bench_params(), planner,
                            engine_config())
        rep = eng.run(sessions_for(concurrency, seed=seed), thr)
        warm = sum(eng.slots.stats.warmup_s.values())
        j = eng.journal.summary()
        rows.append(dict(policy=name,
                         ttft_p95_ms=1e3 * rep.ttft_p95_s,
                         tpot_p95_ms=1e3 * rep.tpot_p95_s,
                         slo=rep.slo_attainment,
                         warmup_s=warm,
                         mean_rebind_us=eng.slots.stats.mean_rebind_us,
                         on_demand_builds=eng.slots.stats.misses,
                         cycles=int(j["cycles"]),
                         preemptions=int(j["preemptions"]),
                         mean_chunk=j["mean_chunk"]))
    return rows


def main():
    rows = run()
    print("fig7: policy,ttft_p95_ms,tpot_p95_ms,slo,warmup_s,"
          "mean_rebind_us,on_demand_builds,cycles,preemptions,mean_chunk")
    for r in rows:
        print(f"fig7,{r['policy']},{r['ttft_p95_ms']:.2f},"
              f"{r['tpot_p95_ms']:.2f},{r['slo']:.3f},{r['warmup_s']:.2f},"
              f"{r['mean_rebind_us']:.1f},{r['on_demand_builds']},"
              f"{r['cycles']},{r['preemptions']},{r['mean_chunk']:.1f}")
    return rows


if __name__ == "__main__":
    main()
