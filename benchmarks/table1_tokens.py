"""Table I reproduction: token distributions of the workload generator
(cold prefill / resume prefill / decode, per paradigm) vs the paper's
published ranges."""
from __future__ import annotations

from repro.serving.workload import table1_statistics

PAPER = {
    "react": dict(cold=(2500, 3500), resume=(30, 127, 56),
                  decode=(27, 127, 40)),
    "plan_execute": dict(cold=(2500, 3500), resume=(125, 421, 251),
                         decode=(33, 141, 60)),
}


def main():
    print("table1: workload,stage,min,max,mean,paper_range")
    ok = True
    for wl, ranges in PAPER.items():
        stats = table1_statistics(wl, n=300)
        for stage, key in [("cold_prefill", "cold"),
                           ("resume_prefill", "resume"),
                           ("decode", "decode")]:
            s = stats[stage]
            pr = ranges[key]
            print(f"table1,{wl},{stage},{s['min']},{s['max']},"
                  f"{s['mean']:.1f},{pr}")
            if stage != "cold_prefill":
                ok &= pr[0] <= s["min"] and s["max"] <= pr[1]
    print(f"table1,within_paper_ranges,{ok}")
    return ok


if __name__ == "__main__":
    main()
