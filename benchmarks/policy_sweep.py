"""Policy-sweep smoke: the unified-semantics contract guard.

Runs **every** registered planner (the paper's comparison set in
``POLICIES`` plus the SLO-class ``priority`` planner) on a tiny
workload through BOTH execution substrates:

  * the real ``ServingEngine`` (plan → dispatch on warmed executables),
  * the fluid ``simulate()`` (the same planner objects over a synthetic
    throughput profile),

and asserts each run completes with nonzero tokens.  Because the two
substrates consume the *same* ``CyclePlanner`` objects (DESIGN.md §9),
this sweep is what catches a policy that works in one and silently
breaks in the other — the drift the plan-based refactor exists to
prevent.

CI runs ``--smoke``; the full mode prints per-policy journal summaries.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.competitive import ThroughputProfile
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.policies import PLANNERS, make_planner
from repro.serving.request import SessionState
from repro.serving.simulator import sessions_from_workload, simulate
from repro.serving.workload import make_workload

TINY = ModelConfig(name="tiny-sweep", family="dense", num_layers=2,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, tie_embeddings=True, source="bench")


def synthetic_profile() -> ThroughputProfile:
    """A plausible monotone profile (tokens/s over the slot grid) — the
    simulator leg must not depend on a slow engine-profiling pass."""
    levels = np.arange(10, 110, 10)
    return ThroughputProfile(
        levels=levels,
        mu_decode=40.0 + 2.0 * levels,
        mu_cold=30.0 * np.sqrt(levels),
        mu_resume=45.0 * np.sqrt(levels))


def run_engine_leg(name: str, params, n_sessions: int,
                   token_scale: float) -> dict:
    ecfg = EngineConfig(num_slots=4, max_seq=512, cycle_budget=80,
                        granularity=8, b_min=8, b_max=128, b_init=32,
                        delta_b=8, control_interval_s=0.05, max_wall_s=90.0)
    sessions = make_workload(n_sessions, workload="react",
                             vocab_size=TINY.vocab_size,
                             token_scale=token_scale,
                             num_system_prompts=1, seed=0, stagger_s=0.02)
    if name == "priority":
        # mixed SLO classes so the preemption path is actually exercised
        for i, s in enumerate(sessions):
            s.slo_class = "interactive" if i % 2 else "batch"
    eng = ServingEngine(TINY, params, PLANNERS[name], ecfg)
    rep = eng.run(sessions)
    assert rep.total_output_tokens > 0, f"{name}: engine emitted no tokens"
    assert all(s.state == SessionState.FINISHED for s in sessions), \
        f"{name}: engine left sessions unfinished"
    return dict(tokens=rep.total_output_tokens,
                wall_s=rep.wall_time_s,
                **{k: int(v) for k, v in eng.journal.summary().items()})


def run_sim_leg(name: str, n_sessions: int, token_scale: float) -> dict:
    ws = make_workload(n_sessions, vocab_size=TINY.vocab_size,
                       token_scale=token_scale, num_system_prompts=1,
                       seed=0, stagger_s=0.02)
    sims = sessions_from_workload(ws)
    if name == "priority":
        for i, s in enumerate(sims):
            s.slo_class = "interactive" if i % 2 else "batch"
    res = simulate(synthetic_profile(), sims,
                   planner=make_planner(name), max_t=120.0)
    assert res.ttfts and res.tpots, f"{name}: simulator produced no samples"
    assert res.prefill_tokens_served > 0, f"{name}: no sim prefill served"
    return dict(ttft_p50=res.summary()["ttft_p50"],
                tpot_p50=res.summary()["tpot_p50"],
                prefill_tokens=res.prefill_tokens_served)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (the CI configuration)")
    ap.add_argument("--agents", type=int, default=0,
                    help="override session count")
    args = ap.parse_args(argv)
    n = args.agents or (3 if args.smoke else 5)
    scale = 0.04 if args.smoke else 0.0625

    params = init_params(TINY, jax.random.PRNGKey(0))
    print("policy_sweep: policy,engine_tokens,engine_cycles,"
          "engine_preemptions,sim_prefill_tokens")
    for name in sorted(PLANNERS):
        e = run_engine_leg(name, params, n, scale)
        s = run_sim_leg(name, n, scale)
        print(f"policy_sweep,{name},{e['tokens']},{e['cycles']},"
              f"{e['preemptions']},{s['prefill_tokens']:.0f}", flush=True)
    print("policy_sweep: OK — every planner completed on both substrates")
    return 0


if __name__ == "__main__":
    sys.exit(main())
