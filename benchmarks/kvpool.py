"""KV pool benchmark: slab snapshot-copy vs paged zero-copy sharing
(DESIGN.md §8).

    PYTHONPATH=src python benchmarks/kvpool.py [--smoke] [--out F]

Measures three things and emits ``BENCH_kvpool.json``:

  * **Prefix hit latency** — restoring a cached shared prefix into a
    fresh slot: the slab pool pays a fused device scatter of the whole
    snapshot (O(prefix bytes)); the paged pool points the slot's block
    table at the shared pages (O(metadata), refcount++).
  * **Park/unpark latency** — the TOOL_WAIT release policy round trip:
    slab = full-slot device gather + scatter; paged = page-reference
    transfer (dense models: zero device work; hybrid would add one
    small SSM point snapshot).
  * **Max concurrent sessions at fixed arena bytes** — the capacity
    unlock: a slab pool pins ``max_seq`` rows per session regardless of
    its real length, so capacity is ``num_slots``; a paged pool with
    the *same* positional arena bytes admits sessions until the page
    allocator is exhausted — actual lengths plus one shared copy of the
    common prefix.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.serving.kvcache import KVCachePool, PagedKVCachePool


def _timeit(fn, reps: int) -> float:
    fn()                                     # warm (compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    if out is not None:
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _paged_cfg(cfg, page_size):
    return dataclasses.replace(cfg, name=f"{cfg.name}-paged",
                               kv_layout="paged", kv_page_size=page_size)


def _registered_pool(make, prefix_len):
    pool = make()
    src = pool.alloc()
    toks = np.arange(prefix_len, dtype=np.int32)
    if isinstance(pool, PagedKVCachePool):
        pool.prepare_append(src, 0, prefix_len)
    pool.lengths[src] = prefix_len
    pool.register_prefix(src, toks)
    return pool, pool.lookup(toks)


# ---------------------------------------------------------------------------
# prefix hit: snapshot scatter vs block-table surgery
# ---------------------------------------------------------------------------

def bench_prefix_hit(cfg, num_slots, max_seq, prefix_len, reps):
    page = cfg.kv_page_size

    def one(make, paged):
        pool, entry = _registered_pool(make, prefix_len)

        def hit():
            d = pool.alloc()
            pool.restore_prefix(d, entry)
            pool.free(d)
            return None if paged else jax.tree_util.tree_leaves(pool.cache)

        t = _timeit(hit, reps)
        return t, pool

    t_slab, _ = one(lambda: KVCachePool(cfg, num_slots, max_seq), False)
    t_paged, pp = one(
        lambda: PagedKVCachePool(_paged_cfg(cfg, page), num_slots, max_seq),
        True)
    assert pp.stats["page_copies"] == 0      # the zero-copy claim, measured
    out = {"prefix_len": prefix_len,
           "slab_snapshot_copy_us": t_slab * 1e6,
           "paged_zero_copy_us": t_paged * 1e6,
           "speedup": t_slab / t_paged}
    print(f"prefix hit  len={prefix_len}  slab={t_slab*1e6:8.0f}us  "
          f"paged={t_paged*1e6:8.2f}us  ({out['speedup']:.0f}x)")
    return out


# ---------------------------------------------------------------------------
# park/unpark round trip
# ---------------------------------------------------------------------------

def bench_park_unpark(cfg, num_slots, max_seq, sess_len, reps):
    page = cfg.kv_page_size

    def one(make, paged):
        pool = make()
        s = pool.alloc()
        if paged:
            pool.prepare_append(s, 0, sess_len)
        pool.lengths[s] = sess_len
        slot = {"s": s}

        def round_trip():
            entry = pool.park(slot["s"])
            slot["s"] = pool.alloc()
            pool.unpark(slot["s"], entry)
            return None if paged else jax.tree_util.tree_leaves(pool.cache)

        return _timeit(round_trip, reps), pool

    t_slab, _ = one(lambda: KVCachePool(cfg, num_slots, max_seq), False)
    t_paged, pp = one(
        lambda: PagedKVCachePool(_paged_cfg(cfg, page), num_slots, max_seq),
        True)
    assert pp.stats["page_copies"] == 0
    out = {"session_len": sess_len,
           "slab_roundtrip_us": t_slab * 1e6,
           "paged_roundtrip_us": t_paged * 1e6,
           "speedup": t_slab / t_paged}
    print(f"park/unpark len={sess_len}  slab={t_slab*1e6:8.0f}us  "
          f"paged={t_paged*1e6:8.2f}us  ({out['speedup']:.0f}x)")
    return out


# ---------------------------------------------------------------------------
# max concurrent sessions at fixed arena bytes
# ---------------------------------------------------------------------------

def bench_capacity(cfg, num_slots, max_seq, sess_len, prefix_len):
    """Same positional arena bytes for both layouts (= ``num_slots``
    full-length stripes).  Sessions have real length ``sess_len`` and
    share a ``prefix_len`` system prompt."""
    page = cfg.kv_page_size
    pcfg = _paged_cfg(cfg, page)
    num_pages = num_slots * (max_seq // page)
    # slot registry sized well past the page budget: the experiment
    # measures the *memory* bound, not the slot bound
    slot_cap = num_pages + 1
    pool = PagedKVCachePool(pcfg, slot_cap, max_seq, num_pages=num_pages)
    arena = pool.arena_bytes()

    toks = np.arange(prefix_len, dtype=np.int32)
    admitted = 0
    entry = None
    try:
        while True:
            s = pool.alloc()
            if entry is None:
                pool.prepare_append(s, 0, prefix_len)
                pool.lengths[s] = prefix_len
                pool.register_prefix(s, toks)
                entry = pool.lookup(toks)
            else:
                pool.restore_prefix(s, entry)
            pool.prepare_append(s, prefix_len, sess_len - prefix_len)
            pool.lengths[s] = sess_len
            admitted += 1
    except RuntimeError:
        pass                                  # page pool exhausted
    out = {"arena_bytes": arena, "max_seq": max_seq, "page_size": page,
           "session_len": sess_len, "shared_prefix_len": prefix_len,
           "slab_sessions": num_slots, "paged_sessions": admitted,
           "capacity_gain": admitted / num_slots}
    print(f"capacity at {arena/1e6:.1f} MB arena: slab={num_slots} "
          f"sessions, paged={admitted} sessions "
          f"({out['capacity_gain']:.1f}x)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few reps (CI)")
    ap.add_argument("--reps", type=int, default=0)
    ap.add_argument("--out", default="BENCH_kvpool.json")
    args = ap.parse_args()

    if args.smoke:
        num_slots, max_seq, page = 4, 256, 32
        prefix_len, sess_len = 64, 96
        reps = args.reps or 5
    else:
        num_slots, max_seq, page = 8, 2048, 64
        prefix_len, sess_len = 512, 768
        reps = args.reps or 20

    cfg = dataclasses.replace(get_smoke_config("smollm-360m"),
                              kv_page_size=page)
    print(f"model={cfg.name} backend={jax.default_backend()} "
          f"max_seq={max_seq} page={page}")
    report = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "smoke": args.smoke,
        "prefix_hit": bench_prefix_hit(cfg, num_slots, max_seq, prefix_len,
                                       reps),
        "park_unpark": bench_park_unpark(cfg, num_slots, max_seq, sess_len,
                                         reps),
        "capacity": bench_capacity(cfg, num_slots, max_seq, sess_len,
                                   prefix_len),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
