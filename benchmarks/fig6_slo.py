"""Fig 6 reproduction: session-level SLO attainment (joint TTFT+TPOT
criterion, §IV-C) under varying concurrency."""
from __future__ import annotations

from benchmarks.common import calibrated_thresholds, make_engine, sessions_for

POLICIES_ORDER = ("agentserve", "pd_static", "chunked", "fcfs")


def run(concurrencies=(3, 4, 5, 6), seed: int = 0):
    thr = calibrated_thresholds()
    rows = []
    for n in concurrencies:
        for policy in POLICIES_ORDER:
            eng = make_engine(policy)
            rep = eng.run(sessions_for(n, seed=seed), thr)
            rows.append((n, policy, rep.slo_attainment))
    return rows


def main(quick: bool = False):
    rows = run((3, 6) if quick else (3, 4, 5, 6))
    print("fig6: concurrency,policy,slo_attainment")
    for n, policy, slo in rows:
        print(f"fig6,{n},{policy},{slo:.3f}")
    return rows


if __name__ == "__main__":
    main()
