"""Open-loop gateway benchmark: goodput vs offered arrival rate.

    PYTHONPATH=src python benchmarks/gateway.py [--rates 2,4] [--smoke]

Boots the online gateway (DESIGN.md §6) on the quickstart config and
drives it with a seeded open-loop Poisson cohort at each offered rate:
one asyncio client task per agent, submitting at the arrival-process
offsets and consuming the token stream to completion.  Emits
``BENCH_gateway.json`` with one goodput-vs-offered-rate row per rate
(goodput, throughput, TTFT/TPOT percentiles, queue-delay breakdown,
429 shed counts) — the open-loop counterpart of the Fig-5 closed-loop
sweep, and the regime where HOL blocking actually manifests.

``--smoke`` is the CI gateway job: ~8 concurrent agents at 2 fixed
rates for a bounded wall clock, asserting every admitted session
completes and an SLO report is emitted.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.gateway import (AgentGateway, GatewayConfig,
                                   drive_open_loop)
from repro.serving.metrics import (OpenLoopReport, SLOThresholds,
                                   build_open_loop_report)
from repro.serving.policies import PLANNERS
from repro.serving.request import SessionState
from repro.serving.workload import make_open_loop_workload


def run_rate(cfg, params, args, rate: float) -> dict:
    """One offered-rate point: fresh engine + gateway, seeded cohort."""
    ecfg = EngineConfig(num_slots=args.slots, max_seq=512,
                        cycle_budget=160, granularity=16,
                        control_interval_s=0.1,
                        max_wall_s=float("inf"))
    engine = ServingEngine(cfg, params, PLANNERS[args.policy], ecfg)
    gateway = AgentGateway(engine, GatewayConfig(
        high_watermark=args.high_watermark, tool_policy=args.tool_policy))
    sessions = make_open_loop_workload(
        args.agents, workload=args.workload, vocab_size=cfg.vocab_size,
        token_scale=args.token_scale, num_system_prompts=1,
        seed=args.seed, rate_rps=rate)
    arrivals = [s.ready_s for s in sessions]

    async def go():
        await gateway.start()
        run = await drive_open_loop(gateway, sessions, arrivals)
        await gateway.stop(timeout_s=args.max_wall)
        return run

    run = asyncio.run(go())
    thr = SLOThresholds(ttft_s=args.slo_ttft_s, tpot_s=args.slo_tpot_s)
    rep = build_open_loop_report(args.policy, run.completed, run.wall_s,
                                 rate, rejected=len(run.rejected),
                                 thresholds=thr)
    assert all(s.state == SessionState.FINISHED for s in run.completed), \
        "admitted sessions must complete"
    return {
        "report": dataclasses.asdict(rep),
        "row": rep.row(),
        "interleaved": run.interleaved(),
        "events": len(run.events),
        "gateway": gateway.stats(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="2,4",
                    help="comma-separated offered rates (req/s)")
    ap.add_argument("--agents", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--policy", default="agentserve",
                    choices=sorted(PLANNERS))
    ap.add_argument("--workload", default="react",
                    choices=["react", "plan_execute"])
    ap.add_argument("--token-scale", type=float, default=0.0625)
    ap.add_argument("--high-watermark", type=int, default=16)
    ap.add_argument("--tool-policy", default="hold",
                    choices=["hold", "release"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-ttft-s", type=float, default=5.0)
    ap.add_argument("--slo-tpot-s", type=float, default=1.0)
    ap.add_argument("--max-wall", type=float, default=120.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gateway smoke: 8 agents, 2 rates, bounded "
                         "wall clock, asserts completion + SLO report")
    ap.add_argument("--out", default="BENCH_gateway.json")
    args = ap.parse_args()

    if args.smoke:
        args.agents, args.token_scale = 8, 0.04
        args.rates = "2,6"

    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rates = [float(r) for r in args.rates.split(",")]

    print(f"model={cfg.name} backend={jax.default_backend()} "
          f"agents={args.agents} rates={rates}")
    print(OpenLoopReport.HEADER)
    results = []
    for rate in rates:
        res = run_rate(cfg, params, args, rate)
        results.append(res)
        print(res["row"], flush=True)

    report = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "agents": args.agents,
        "slots": args.slots,
        "workload": args.workload,
        "token_scale": args.token_scale,
        "high_watermark": args.high_watermark,
        "rates": results,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if args.smoke:
        assert all(r["report"]["completed"] > 0 for r in results)
        assert all(np.isfinite(r["report"]["slo_attainment"])
                   for r in results), "SLO report must be emitted"
        assert any(r["interleaved"] for r in results), \
            "concurrent streams must interleave"


if __name__ == "__main__":
    main()
