"""Fig 5 reproduction: TTFT / TPOT / throughput under varying agent
concurrency (3-6) for AgentServe vs the three baselines, on both
workload paradigms (ReAct, Plan-and-Execute)."""
from __future__ import annotations

from benchmarks.common import calibrated_thresholds, make_engine, sessions_for
from repro.serving.metrics import ServingReport

POLICIES_ORDER = ("agentserve", "pd_static", "chunked", "fcfs")


def run(concurrencies=(3, 4, 5, 6), workloads=("react", "plan_execute"),
        seeds=(0,)):
    thr = calibrated_thresholds()
    rows = []
    for wl in workloads:
        for n in concurrencies:
            for policy in POLICIES_ORDER:
                for seed in seeds:
                    eng = make_engine(policy)
                    sess = sessions_for(n, workload=wl, seed=seed)
                    rep = eng.run(sess, thr)
                    rows.append((wl, n, rep))
    return rows


def main(quick: bool = False):
    rows = run(concurrencies=(3, 6) if quick else (3, 4, 5, 6),
               workloads=("react",) if quick else ("react", "plan_execute"))
    print("fig5: workload,concurrency," + ServingReport.HEADER)
    for wl, n, rep in rows:
        print(f"fig5,{wl},{n},{rep.row()}")
    return rows


if __name__ == "__main__":
    main()
