"""Hot-path microbenchmark: decode dispatch overhead + resume packing.

    PYTHONPATH=src python benchmarks/hotpath.py [--steps N] [--out F]

Measures, on the quickstart (smollm-360m smoke) config:

  * the seed per-step decode path (per-token host sync: block, logits
    copy, NumPy argmax, where-select commit, lengths re-upload),
  * the fused device-resident step (``forward_decode_fused``, donated
    cache, no per-token sync),
  * the K-step megastep (one ``lax.scan`` executable per K tokens),
  * serial batch-1 vs batched [M, bucket] resume prefill,

and emits ``BENCH_hotpath.json`` with decode tokens/s, per-token
dispatch overhead (per-token time minus the megastep floor) and resume
throughput — the perf trajectory anchor for DESIGN.md §3.

It also runs a full ``ServingEngine`` workload to capture the
*measured* dispatch-gap histogram (host gap between consecutive decode
dispatches, p50/p95/p99 — the ROADMAP host-overhead item) and the
telemetry-overhead self-check: best-of-N paired runs with span tracing
on vs ``telemetry=False``, asserted <2% under ``--smoke``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine, \
    get_executables
from repro.serving.kvcache import KVCachePool
from repro.serving.workload import make_workload

ECFG = EngineConfig(num_slots=8, max_seq=512, cycle_budget=160,
                    granularity=16, b_min=16, b_max=256, b_init=64)
CTX = 128            # cached context per slot during decode timing
ACTIVE = 6           # active lanes out of num_slots (sessions churn)
MEGA_K = 8
RESUME_M, RESUME_BUCKET = 4, 64


def _fresh_state(cfg, params, ex):
    pool = KVCachePool(cfg, ECFG.num_slots, ECFG.max_seq)
    B = ECFG.num_slots
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, CTX)),
                       jnp.int32)
    for slot in range(B):
        lg, pool.cache = ex.prefill(params, pool.cache, toks,
                                    jnp.int32(slot), jnp.int32(0),
                                    jnp.int32(CTX - 1))
        pool.lengths[slot] = CTX
    jax.block_until_ready(lg)
    mask = np.zeros((B,), bool)
    mask[:ACTIVE] = True
    tokens = rng.integers(0, cfg.vocab_size, size=(B,)).astype(np.int32)
    return pool, tokens, mask


def bench_seed_steps(cfg, params, ex, steps):
    """The seed engine's per-token path, faithfully."""
    pool, tokens, mask = _fresh_state(cfg, params, ex)
    lengths = pool.lengths

    def one_step():
        logits, new_cache = ex.decode(params, pool.cache,
                                      jnp.asarray(tokens),
                                      jnp.asarray(lengths))
        logits = np.asarray(jax.block_until_ready(logits))
        pool.commit(new_cache, mask)
        for b in np.nonzero(mask)[0]:
            lengths[b] += 1
            tokens[b] = logits[b].argmax()

    one_step()                      # warm
    t0 = time.perf_counter()
    for _ in range(steps):
        one_step()
    return (time.perf_counter() - t0) / steps


def bench_fused_steps(cfg, params, ex, steps):
    pool, tokens, mask = _fresh_state(cfg, params, ex)
    t = jnp.asarray(tokens)
    l = jnp.asarray(pool.lengths)
    a = jnp.asarray(mask)
    t, pool.cache, l = ex.fused(params, pool.cache, t, l, a)   # warm
    jax.block_until_ready(t)
    t0 = time.perf_counter()
    for _ in range(steps):
        t, pool.cache, l = ex.fused(params, pool.cache, t, l, a)
    jax.block_until_ready(t)
    return (time.perf_counter() - t0) / steps


def bench_megastep(cfg, params, ex, steps):
    pool, tokens, mask = _fresh_state(cfg, params, ex)
    fn = ex.megastep(MEGA_K)
    t = jnp.asarray(tokens)
    l = jnp.asarray(pool.lengths)
    a = jnp.asarray(mask)
    _, t, pool.cache, l = fn(params, pool.cache, t, l, a)      # warm
    jax.block_until_ready(t)
    iters = max(1, steps // MEGA_K)
    t0 = time.perf_counter()
    for _ in range(iters):
        _, t, pool.cache, l = fn(params, pool.cache, t, l, a)
    jax.block_until_ready(t)
    return (time.perf_counter() - t0) / (iters * MEGA_K)


def bench_resume(cfg, params, ex, reps):
    """Serial batch-1 vs batched [M, bucket] resume prefill."""
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    size=(RESUME_M, RESUME_BUCKET)),
                       jnp.int32)
    slots = jnp.arange(RESUME_M, dtype=jnp.int32)
    lidx = jnp.full((RESUME_M,), RESUME_BUCKET - 1, jnp.int32)

    pool, _, _ = _fresh_state(cfg, params, ex)
    lens = jnp.full((RESUME_M,), CTX, jnp.int32)

    def serial():
        lg = None
        for i in range(RESUME_M):
            lg, pool.cache = ex.prefill(params, pool.cache, rows[i][None],
                                        jnp.int32(i), jnp.int32(CTX),
                                        jnp.int32(RESUME_BUCKET - 1))
            np.asarray(lg)          # seed path blocked per chunk
        return lg

    def batched():
        lg, pool.cache = ex.resume(params, pool.cache, rows, slots, lens,
                                   lidx)
        return lg

    out = {}
    for name, fn in [("serial", serial), ("batched", batched)]:
        jax.block_until_ready(fn())     # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out_l = fn()
        jax.block_until_ready(out_l)
        dt = (time.perf_counter() - t0) / reps
        out[name] = {"s_per_call": dt,
                     "tok_s": RESUME_M * RESUME_BUCKET / dt}
    out["speedup_batched_vs_serial"] = (out["serial"]["s_per_call"]
                                        / out["batched"]["s_per_call"])
    out.update(m=RESUME_M, bucket=RESUME_BUCKET)
    return out


def _engine_run(cfg, params, telemetry: bool, agents: int,
                token_scale: float):
    """One closed-loop engine run; returns (tok/s, report, engine)."""
    ecfg = dataclasses.replace(ECFG, telemetry=telemetry,
                               control_interval_s=0.1)
    eng = ServingEngine(cfg, params, "agentserve", ecfg)
    sessions = make_workload(agents, workload="react",
                             vocab_size=cfg.vocab_size,
                             token_scale=token_scale,
                             num_system_prompts=1, seed=0)
    rep = eng.run(sessions)
    return rep.throughput_tok_s, rep, eng


def bench_engine_telemetry(cfg, params, *, agents: int,
                           token_scale: float, reps: int):
    """Dispatch-gap histogram + telemetry-overhead self-check.

    Overhead runs are *interleaved* (on, off, on, off, ...) and
    compared best-vs-best so machine noise (CI neighbours, thermal
    drift) hits both arms equally instead of biasing one."""
    best_on, best_off = 0.0, 0.0
    gap_stats = None
    report_on = None
    for _ in range(reps):
        tok_on, rep, eng = _engine_run(cfg, params, True, agents,
                                       token_scale)
        if tok_on > best_on:
            best_on, report_on = tok_on, rep
            gap_stats = eng.stats()
        tok_off, _, _ = _engine_run(cfg, params, False, agents,
                                    token_scale)
        best_off = max(best_off, tok_off)
    overhead_pct = (best_off - best_on) / best_off * 100.0
    report_on.telemetry_overhead_pct = overhead_pct
    return {
        "agents": agents, "token_scale": token_scale, "runs": reps,
        "dispatch_gap_ms": {
            "p50": gap_stats["dispatch_gap_s_p50"] * 1e3,
            "p95": gap_stats["dispatch_gap_s_p95"] * 1e3,
            "p99": gap_stats["dispatch_gap_s_p99"] * 1e3,
            "count": gap_stats["dispatch_gap_s_count"],
        },
        "device_wait_ms_p95": gap_stats["device_wait_s_p95"] * 1e3,
        "cycle_host_ms_p95": gap_stats["cycle_host_s_p95"] * 1e3,
        "telemetry_overhead": {
            "on_tok_s_best": best_on, "off_tok_s_best": best_off,
            "overhead_pct": overhead_pct,
        },
    }, report_on


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=0,
                    help="decode steps per variant (0 = auto-calibrate)")
    ap.add_argument("--resume-reps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="fixed small step/rep counts (CI perf-harness "
                         "smoke: exercises every path, no stable numbers)")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    args = ap.parse_args()

    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ex = get_executables(cfg, ECFG.num_slots, ECFG.max_seq, ECFG.moe_mode)

    steps = args.steps
    if args.smoke:
        steps, args.resume_reps = steps or MEGA_K * 2, 3
    if steps <= 0:
        probe = bench_seed_steps(cfg, params, ex, 8)
        steps = int(np.clip(3.0 / probe, 32, 1500))     # ~3 s per variant
    print(f"model={cfg.name} backend={jax.default_backend()} "
          f"decode steps/variant={steps}")

    t_seed = bench_seed_steps(cfg, params, ex, steps)
    t_fused = bench_fused_steps(cfg, params, ex, steps)
    t_mega = bench_megastep(cfg, params, ex, steps)
    resume = bench_resume(cfg, params, ex, args.resume_reps)
    engine_reps = 2 if args.smoke else 5
    engine, rep_on = bench_engine_telemetry(
        cfg, params, agents=ACTIVE,
        token_scale=0.0625 if args.smoke else 0.125, reps=engine_reps)

    def tok_s(t):
        return ACTIVE / t

    report = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "decode": {
            "slots": ECFG.num_slots, "active": ACTIVE, "ctx": CTX,
            "steps": steps, "megastep_k": MEGA_K,
            "seed_per_step": {"ms_per_step": t_seed * 1e3,
                              "tok_s": tok_s(t_seed)},
            "fused": {"ms_per_step": t_fused * 1e3, "tok_s": tok_s(t_fused)},
            "megastep": {"ms_per_step": t_mega * 1e3, "tok_s": tok_s(t_mega)},
            "speedup_fused_vs_seed": t_seed / t_fused,
            "speedup_megastep_vs_seed": t_seed / t_mega,
            # megastep is the dispatch-amortised floor: anything above it
            # is per-step dispatch + host-sync overhead
            "dispatch_overhead_ms_per_step": {
                "seed_per_step": (t_seed - t_mega) * 1e3,
                "fused": (t_fused - t_mega) * 1e3,
            },
        },
        "resume": resume,
        "engine": engine,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    d = report["decode"]
    print(f"decode tok/s  seed={d['seed_per_step']['tok_s']:.1f}  "
          f"fused={d['fused']['tok_s']:.1f} "
          f"({d['speedup_fused_vs_seed']:.2f}x)  "
          f"megastep{MEGA_K}={d['megastep']['tok_s']:.1f} "
          f"({d['speedup_megastep_vs_seed']:.2f}x)")
    print(f"resume tok/s  serial={resume['serial']['tok_s']:.0f}  "
          f"batched={resume['batched']['tok_s']:.0f} "
          f"({resume['speedup_batched_vs_serial']:.2f}x)")
    g = engine["dispatch_gap_ms"]
    ov = engine["telemetry_overhead"]
    print(f"dispatch gap ms  p50={g['p50']:.3f} p95={g['p95']:.3f} "
          f"p99={g['p99']:.3f} (n={g['count']:.0f})")
    from repro.serving.metrics import ServingReport
    print(ServingReport.HEADER)
    print(rep_on.row(), flush=True)
    print(f"telemetry overhead {ov['overhead_pct']:.2f}% "
          f"(on={ov['on_tok_s_best']:.1f} off={ov['off_tok_s_best']:.1f} "
          f"tok/s, best of {engine['runs']})")
    if args.smoke:
        assert ov["overhead_pct"] < 2.0, \
            f"telemetry overhead {ov['overhead_pct']:.2f}% >= 2%"
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
