"""Hot-path microbenchmark: decode dispatch overhead + resume packing.

    PYTHONPATH=src python benchmarks/hotpath.py [--steps N] [--out F]

Measures, on the quickstart (smollm-360m smoke) config:

  * the seed per-step decode path (per-token host sync: block, logits
    copy, NumPy argmax, where-select commit, lengths re-upload),
  * the fused device-resident step (``forward_decode_fused``, donated
    cache, no per-token sync),
  * the K-step megastep (one ``lax.scan`` executable per K tokens),
  * serial batch-1 vs batched [M, bucket] resume prefill,

and emits ``BENCH_hotpath.json`` with decode tokens/s, per-token
dispatch overhead (per-token time minus the megastep floor) and resume
throughput — the perf trajectory anchor for DESIGN.md §3.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, get_executables
from repro.serving.kvcache import KVCachePool

ECFG = EngineConfig(num_slots=8, max_seq=512, cycle_budget=160,
                    granularity=16, b_min=16, b_max=256, b_init=64)
CTX = 128            # cached context per slot during decode timing
ACTIVE = 6           # active lanes out of num_slots (sessions churn)
MEGA_K = 8
RESUME_M, RESUME_BUCKET = 4, 64


def _fresh_state(cfg, params, ex):
    pool = KVCachePool(cfg, ECFG.num_slots, ECFG.max_seq)
    B = ECFG.num_slots
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, CTX)),
                       jnp.int32)
    for slot in range(B):
        lg, pool.cache = ex.prefill(params, pool.cache, toks,
                                    jnp.int32(slot), jnp.int32(0),
                                    jnp.int32(CTX - 1))
        pool.lengths[slot] = CTX
    jax.block_until_ready(lg)
    mask = np.zeros((B,), bool)
    mask[:ACTIVE] = True
    tokens = rng.integers(0, cfg.vocab_size, size=(B,)).astype(np.int32)
    return pool, tokens, mask


def bench_seed_steps(cfg, params, ex, steps):
    """The seed engine's per-token path, faithfully."""
    pool, tokens, mask = _fresh_state(cfg, params, ex)
    lengths = pool.lengths

    def one_step():
        logits, new_cache = ex.decode(params, pool.cache,
                                      jnp.asarray(tokens),
                                      jnp.asarray(lengths))
        logits = np.asarray(jax.block_until_ready(logits))
        pool.commit(new_cache, mask)
        for b in np.nonzero(mask)[0]:
            lengths[b] += 1
            tokens[b] = logits[b].argmax()

    one_step()                      # warm
    t0 = time.perf_counter()
    for _ in range(steps):
        one_step()
    return (time.perf_counter() - t0) / steps


def bench_fused_steps(cfg, params, ex, steps):
    pool, tokens, mask = _fresh_state(cfg, params, ex)
    t = jnp.asarray(tokens)
    l = jnp.asarray(pool.lengths)
    a = jnp.asarray(mask)
    t, pool.cache, l = ex.fused(params, pool.cache, t, l, a)   # warm
    jax.block_until_ready(t)
    t0 = time.perf_counter()
    for _ in range(steps):
        t, pool.cache, l = ex.fused(params, pool.cache, t, l, a)
    jax.block_until_ready(t)
    return (time.perf_counter() - t0) / steps


def bench_megastep(cfg, params, ex, steps):
    pool, tokens, mask = _fresh_state(cfg, params, ex)
    fn = ex.megastep(MEGA_K)
    t = jnp.asarray(tokens)
    l = jnp.asarray(pool.lengths)
    a = jnp.asarray(mask)
    _, t, pool.cache, l = fn(params, pool.cache, t, l, a)      # warm
    jax.block_until_ready(t)
    iters = max(1, steps // MEGA_K)
    t0 = time.perf_counter()
    for _ in range(iters):
        _, t, pool.cache, l = fn(params, pool.cache, t, l, a)
    jax.block_until_ready(t)
    return (time.perf_counter() - t0) / (iters * MEGA_K)


def bench_resume(cfg, params, ex, reps):
    """Serial batch-1 vs batched [M, bucket] resume prefill."""
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    size=(RESUME_M, RESUME_BUCKET)),
                       jnp.int32)
    slots = jnp.arange(RESUME_M, dtype=jnp.int32)
    lidx = jnp.full((RESUME_M,), RESUME_BUCKET - 1, jnp.int32)

    pool, _, _ = _fresh_state(cfg, params, ex)
    lens = jnp.full((RESUME_M,), CTX, jnp.int32)

    def serial():
        lg = None
        for i in range(RESUME_M):
            lg, pool.cache = ex.prefill(params, pool.cache, rows[i][None],
                                        jnp.int32(i), jnp.int32(CTX),
                                        jnp.int32(RESUME_BUCKET - 1))
            np.asarray(lg)          # seed path blocked per chunk
        return lg

    def batched():
        lg, pool.cache = ex.resume(params, pool.cache, rows, slots, lens,
                                   lidx)
        return lg

    out = {}
    for name, fn in [("serial", serial), ("batched", batched)]:
        jax.block_until_ready(fn())     # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out_l = fn()
        jax.block_until_ready(out_l)
        dt = (time.perf_counter() - t0) / reps
        out[name] = {"s_per_call": dt,
                     "tok_s": RESUME_M * RESUME_BUCKET / dt}
    out["speedup_batched_vs_serial"] = (out["serial"]["s_per_call"]
                                        / out["batched"]["s_per_call"])
    out.update(m=RESUME_M, bucket=RESUME_BUCKET)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=0,
                    help="decode steps per variant (0 = auto-calibrate)")
    ap.add_argument("--resume-reps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="fixed small step/rep counts (CI perf-harness "
                         "smoke: exercises every path, no stable numbers)")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    args = ap.parse_args()

    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ex = get_executables(cfg, ECFG.num_slots, ECFG.max_seq, ECFG.moe_mode)

    steps = args.steps
    if args.smoke:
        steps, args.resume_reps = steps or MEGA_K * 2, 3
    if steps <= 0:
        probe = bench_seed_steps(cfg, params, ex, 8)
        steps = int(np.clip(3.0 / probe, 32, 1500))     # ~3 s per variant
    print(f"model={cfg.name} backend={jax.default_backend()} "
          f"decode steps/variant={steps}")

    t_seed = bench_seed_steps(cfg, params, ex, steps)
    t_fused = bench_fused_steps(cfg, params, ex, steps)
    t_mega = bench_megastep(cfg, params, ex, steps)
    resume = bench_resume(cfg, params, ex, args.resume_reps)

    def tok_s(t):
        return ACTIVE / t

    report = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "decode": {
            "slots": ECFG.num_slots, "active": ACTIVE, "ctx": CTX,
            "steps": steps, "megastep_k": MEGA_K,
            "seed_per_step": {"ms_per_step": t_seed * 1e3,
                              "tok_s": tok_s(t_seed)},
            "fused": {"ms_per_step": t_fused * 1e3, "tok_s": tok_s(t_fused)},
            "megastep": {"ms_per_step": t_mega * 1e3, "tok_s": tok_s(t_mega)},
            "speedup_fused_vs_seed": t_seed / t_fused,
            "speedup_megastep_vs_seed": t_seed / t_mega,
            # megastep is the dispatch-amortised floor: anything above it
            # is per-step dispatch + host-sync overhead
            "dispatch_overhead_ms_per_step": {
                "seed_per_step": (t_seed - t_mega) * 1e3,
                "fused": (t_fused - t_mega) * 1e3,
            },
        },
        "resume": resume,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    d = report["decode"]
    print(f"decode tok/s  seed={d['seed_per_step']['tok_s']:.1f}  "
          f"fused={d['fused']['tok_s']:.1f} "
          f"({d['speedup_fused_vs_seed']:.2f}x)  "
          f"megastep{MEGA_K}={d['megastep']['tok_s']:.1f} "
          f"({d['speedup_megastep_vs_seed']:.2f}x)")
    print(f"resume tok/s  serial={resume['serial']['tok_s']:.0f}  "
          f"batched={resume['batched']['tok_s']:.0f} "
          f"({resume['speedup_batched_vs_serial']:.2f}x)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
