"""Prefill-path benchmark: length-pruned chunked prefill, packed cold
prefills and fused prefix restore (DESIGN.md §4).

    PYTHONPATH=src python benchmarks/prefill.py [--smoke] [--out F]

Measures three things and emits ``BENCH_prefill.json``:

  * **Chunked prefill scaling** — per-chunk attention cost of the seed
    ``blocked_attention`` path (streams all ``max_seq`` padded KV tiles
    per chunk) vs the length-pruned path (streams only tiles up to the
    chunk's causal+valid bound).  On TPU the pruning is the Pallas
    kernel's scalar-prefetched DMA elision; on CPU the kernel only runs
    in interpret mode (parity, no perf), so the pruned cost is measured
    with the *reference* realisation of the same tile bound: the KV
    extent is sliced host-side to the pruned tile count before the
    blocked scan.  The headline: prefill tokens/s at short contexts
    (≤25% of ``max_seq``) must not be priced at the full padded extent.
  * **Packed cold prefill** — M pending cold prefills in one
    ``[M, bucket]`` batched executable vs M serial batch-1 chunk calls
    (the engine's `_cold_pack_step` vs the seed loop).
  * **Prefix restore** — the seed per-leaf ``.at[].set`` dispatch loop
    vs the fused jitted scatter (``KVCachePool.restore_prefix``).
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.models.attention import blocked_attention
from repro.serving.engine import EngineConfig, get_executables
from repro.serving.kvcache import KVCachePool


def _timeit(fn, reps: int) -> float:
    fn()                                     # warm (compile)
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# chunked prefill: cost vs actual context length
# ---------------------------------------------------------------------------

def bench_chunked_scaling(max_seq: int, chunk: int, block: int, reps: int):
    B, H, Hk, hd = 2, 4, 2, 64
    rng = np.random.default_rng(0)
    kc = jnp.asarray(rng.standard_normal((B, max_seq, Hk, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, max_seq, Hk, hd)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, chunk, H, hd)), jnp.float32)

    attn = jax.jit(functools.partial(
        blocked_attention, causal=True, window=0, block_size=block))

    def seed_chunks(nchunks):
        outs = []
        for i in range(nchunks):
            qo = jnp.full((B,), i * chunk, jnp.int32)
            outs.append(attn(q, kc, vc, q_offset=qo,
                             lengths=qo + chunk))
        return outs[-1]

    def pruned_chunks(nchunks):
        outs = []
        for i in range(nchunks):
            # the kernel's tile bound: keys beyond q_offset + chunk are
            # causally dead / never written; realise it as a host-side
            # extent slice (offsets are host-known at dispatch time)
            extent = min(-(-((i + 1) * chunk) // block) * block, max_seq)
            qo = jnp.full((B,), i * chunk, jnp.int32)
            outs.append(attn(q, kc[:, :extent], vc[:, :extent],
                             q_offset=qo, lengths=qo + chunk))
        return outs[-1]

    rows = []
    for ctx in [max_seq // 8, max_seq // 4, max_seq // 2, max_seq]:
        n = ctx // chunk
        t_seed = _timeit(lambda: seed_chunks(n), reps)
        t_pruned = _timeit(lambda: pruned_chunks(n), reps)
        rows.append({
            "ctx": ctx, "frac_of_max_seq": ctx / max_seq,
            "seed_tok_s": ctx / t_seed, "pruned_tok_s": ctx / t_pruned,
            "seed_s": t_seed, "pruned_s": t_pruned,
            "speedup": t_seed / t_pruned,
        })
        print(f"ctx={ctx:5d} ({ctx/max_seq:4.0%} of max_seq)  "
              f"seed={ctx/t_seed:9.0f} tok/s  "
              f"pruned={ctx/t_pruned:9.0f} tok/s  "
              f"({t_seed/t_pruned:.2f}x)")
    short = [r for r in rows if r["frac_of_max_seq"] <= 0.25]
    return {
        "max_seq": max_seq, "chunk": chunk, "block": block,
        "batch": B, "heads": H, "kv_heads": Hk, "head_dim": hd,
        "contexts": rows,
        "speedup_short_ctx": min(r["speedup"] for r in short),
    }


# ---------------------------------------------------------------------------
# packed vs serial cold prefill (engine executables)
# ---------------------------------------------------------------------------

def bench_packed_cold(cfg, params, ex, ecfg, m: int, bucket: int, reps: int):
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(m, bucket)),
                       jnp.int32)
    slots = jnp.arange(m, dtype=jnp.int32)
    lens = jnp.zeros((m,), jnp.int32)           # cold: empty slots
    lidx = jnp.full((m,), bucket - 1, jnp.int32)
    pool = KVCachePool(cfg, ecfg.num_slots, ecfg.max_seq)
    cache = pool.cache
    # ex.resume donates its cache argument: keep a rolling reference
    # (the engine's own convention) instead of re-copying per call
    state = {"c": jax.tree.map(jnp.copy, cache)}

    def serial():
        lg = None
        for i in range(m):                   # ex.prefill does not donate
            lg, _ = ex.prefill(params, cache, rows[i][None],
                               jnp.int32(i), jnp.int32(0),
                               jnp.int32(bucket - 1))
        return lg

    def packed():
        lg, state["c"] = ex.resume(params, state["c"], rows, slots, lens,
                                   lidx)
        return lg

    t_serial = _timeit(serial, reps)
    t_packed = _timeit(packed, reps)
    out = {"m": m, "bucket": bucket,
           "serial": {"s_per_round": t_serial,
                      "tok_s": m * bucket / t_serial},
           "packed": {"s_per_round": t_packed,
                      "tok_s": m * bucket / t_packed},
           "speedup_packed_vs_serial": t_serial / t_packed}
    print(f"cold prefill m={m} bucket={bucket}  "
          f"serial={out['serial']['tok_s']:.0f} tok/s  "
          f"packed={out['packed']['tok_s']:.0f} tok/s  "
          f"({out['speedup_packed_vs_serial']:.2f}x)")
    return out


# ---------------------------------------------------------------------------
# prefix restore: per-leaf dispatch loop vs fused scatter
# ---------------------------------------------------------------------------

def bench_prefix_restore(cfg, ecfg, prefix_len: int, reps: int):
    pool = KVCachePool(cfg, ecfg.num_slots, ecfg.max_seq)
    src = pool.alloc()
    dst = pool.alloc()
    toks = np.arange(prefix_len, dtype=np.int32)
    pool.lengths[src] = prefix_len
    pool.register_prefix(src, toks)
    entry = pool.lookup(toks)
    leaves = len(jax.tree_util.tree_leaves(pool.cache))

    def per_leaf():                      # the seed implementation
        return jax.tree.map(
            lambda leaf, snap: leaf.at[:, dst].set(snap),
            pool.cache, entry.snapshot)

    def fused():
        pool.restore_prefix(dst, entry)
        return pool.cache

    t_leaf = _timeit(per_leaf, reps)
    t_fused = _timeit(fused, reps)
    out = {"prefix_len": prefix_len, "cache_leaves": leaves,
           "per_leaf_us": t_leaf * 1e6, "fused_us": t_fused * 1e6,
           "speedup": t_leaf / t_fused}
    print(f"prefix restore ({leaves} leaves)  per-leaf={t_leaf*1e6:.0f}us  "
          f"fused={t_fused*1e6:.0f}us  ({out['speedup']:.2f}x)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few reps (CI)")
    ap.add_argument("--reps", type=int, default=0)
    ap.add_argument("--out", default="BENCH_prefill.json")
    args = ap.parse_args()

    if args.smoke:
        max_seq, chunk, block = 512, 64, 64
        reps = args.reps or 3
        m, bucket = 2, 32
    else:
        max_seq, chunk, block = 2048, 128, 128
        reps = args.reps or 10
        m, bucket = 4, 64

    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=8, max_seq=512, cycle_budget=160,
                        granularity=16, b_min=16, b_max=256, b_init=64)
    ex = get_executables(cfg, ecfg.num_slots, ecfg.max_seq, ecfg.moe_mode)
    print(f"model={cfg.name} backend={jax.default_backend()} "
          f"max_seq={max_seq} chunk={chunk}")

    report = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "smoke": args.smoke,
        "chunked_prefill": bench_chunked_scaling(max_seq, chunk, block, reps),
        "packed_cold": bench_packed_cold(cfg, params, ex, ecfg, m, bucket,
                                         reps),
        # hybrid config: the per-leaf dispatch cost scales with cache
        # leaves (attn KV + per-layer SSM states), which is the effect
        # the fused scatter removes
        "prefix_restore": bench_prefix_restore(
            get_smoke_config("jamba-1.5-large-398b"), ecfg, 128,
            max(reps, 5)),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
