"""Data pipeline: deterministic synthetic-corpus token stream.

A real deployment would stream tokenised text; the contract requires the
substrate, not a dataset.  The pipeline generates a reproducible corpus
with Zipfian unigram statistics plus Markov bigram structure — enough
signal that the training examples show a genuinely decreasing loss — and
serves fixed-shape batches with host-side prefetch semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticCorpus:
    """Markov-chain corpus with Zipf marginals (learnable structure)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        marginal = ranks ** (-cfg.zipf_a)
        marginal /= marginal.sum()
        # sparse-ish transition structure: each token prefers ~8 successors
        self._succ = rng.integers(0, V, size=(V, 8))
        self._marginal = marginal
        self._rng = rng

    def batches(self) -> Iterator[dict]:
        """Yields {"tokens": [B, S]} — the loss shifts targets internally."""
        cfg = self.cfg
        while True:
            toks = np.empty((cfg.batch_size, cfg.seq_len), np.int32)
            cur = self._rng.choice(cfg.vocab_size, p=self._marginal,
                                   size=cfg.batch_size)
            toks[:, 0] = cur
            for t in range(1, cfg.seq_len):
                stay = self._rng.random(cfg.batch_size) < 0.8
                nxt_idx = self._rng.integers(0, 8, cfg.batch_size)
                markov = self._succ[cur, nxt_idx]
                fresh = self._rng.choice(cfg.vocab_size, p=self._marginal,
                                         size=cfg.batch_size)
                cur = np.where(stay, markov, fresh).astype(np.int32)
                toks[:, t] = cur
            yield {"tokens": toks}
