"""AdamW in pure JAX (no optax), with configurable state dtype.

``state_dtype=bfloat16`` is used for the giant MoE/hybrid configs
(Mixtral-8x22B, Jamba-1.5-Large) so the full train_step fits v5e HBM at
the assigned mesh sizes — the MaxText-style bf16-optimizer-state
trade-off; f32 elsewhere (see EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Any = jnp.float32


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * prog)
    return cfg.lr * warm * cos


def init_opt_state(cfg: AdamWConfig, params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        u = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return (p_new.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
