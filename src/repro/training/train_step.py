"""Training step: causal-LM (or masked-unit encoder) loss + AdamW update.

Used by (a) the train_4k dry-run shape for every assigned architecture
and (b) the examples/train_slm.py end-to-end driver.  Remat (scan-level
``jax.checkpoint``) keeps train_4k activations within HBM.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_train
from repro.training.optimizer import (AdamWConfig, OptState, apply_updates,
                                      init_opt_state)


def _chunked_ce(params, cfg: ModelConfig, h, targets, mask, chunk: int):
    """CE over sequence chunks: the [B, chunk, V] logits exist only inside
    a rematted scan body, so the full [B, S, V] logits (GBs at 4k x 200k
    vocab) are never materialised — forward or backward."""
    from repro.models.model import _logits
    B, S, d = h.shape
    n = S // chunk

    def body(carry, xs):
        hc, tc, mc = xs
        logits = _logits(params, cfg, hc)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + jnp.sum(nll * mc), cnt + jnp.sum(mc)), None

    xs = (h.reshape(B, n, chunk, d).swapaxes(0, 1),
          targets.reshape(B, n, chunk).swapaxes(0, 1),
          mask.reshape(B, n, chunk).swapaxes(0, 1))
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)), xs)
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg: ModelConfig, tokens, *, embeds=None, labels=None,
            moe_mode: str = "gmm", remat: bool = True, moe_shards: int = 1,
            ce_chunk: int = 0):
    """Next-token CE (decoder) or per-frame unit CE (encoder).

    For encoder-only (HuBERT) the labels are the masked-unit targets with
    the same shape as the frame sequence.  ``ce_chunk`` > 0 enables the
    memory-bounded chunked CE (production/dry-run path)."""
    lbl = labels if labels is not None else tokens
    if ce_chunk:
        h, aux = forward_train(params, cfg, tokens, embeds=embeds,
                               moe_mode=moe_mode, remat=remat,
                               moe_shards=moe_shards, return_hidden=True)
        B, S, _ = h.shape
        if cfg.encoder_only:
            targets, mask = lbl, jnp.ones((B, S), jnp.float32)
        else:
            targets = jnp.concatenate(
                [lbl[:, 1:], jnp.zeros((B, 1), lbl.dtype)], axis=1)
            mask = jnp.concatenate(
                [jnp.ones((B, S - 1), jnp.float32),
                 jnp.zeros((B, 1), jnp.float32)], axis=1)
        ce = _chunked_ce(params, cfg, h, targets, mask,
                         min(ce_chunk, S))
        loss = ce
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_weight * aux / max(cfg.num_layers, 1)
        return loss, {"ce": ce, "aux": aux}

    logits, aux = forward_train(params, cfg, tokens, embeds=embeds,
                                moe_mode=moe_mode, remat=remat,
                                moe_shards=moe_shards)
    if cfg.encoder_only:
        targets = lbl
        logit_slice = logits
    else:
        targets = lbl[:, 1:]
        logit_slice = logits[:, :-1]
    logp = jax.nn.log_softmax(logit_slice.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux / max(cfg.num_layers, 1)
    return loss, {"ce": nll.mean(), "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    moe_mode: str = "gmm", remat: bool = True,
                    moe_shards: int = 1, ce_chunk: int = 0,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, stats).

    batch: {"tokens": [B, S]} or {"embeds": [B, S, d], "labels": [B, S]}.
    ``microbatches`` > 1 enables gradient accumulation: activation memory
    scales with B/microbatches while the optimizer sees the full global
    batch (used by the giant configs to fit v5e HBM)."""

    def loss_fn(p, mb):
        return lm_loss(p, cfg, mb.get("tokens"), embeds=mb.get("embeds"),
                       labels=mb.get("labels"), moe_mode=moe_mode,
                       remat=remat, moe_shards=moe_shards, ce_chunk=ce_chunk)

    def train_step(params, opt_state: OptState, batch: Dict[str, Any]):
        if microbatches == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            n = microbatches
            mb_batch = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), parts

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g_acc, l_sum), parts_all = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree.map(lambda g: g / n, g_acc)
            loss = l_sum / n
            parts = jax.tree.map(lambda x: x.mean(0), parts_all)
        params, opt_state, ostats = apply_updates(
            opt_cfg, params, grads, opt_state)
        stats = {"loss": loss, **parts, **ostats}
        return params, opt_state, stats

    return train_step
