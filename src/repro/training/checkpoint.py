"""Checkpointing: numpy-archive save/restore of params + optimizer state.

Flat-path .npz format (no external deps).  Restores onto the caller's
sharding by default placement; dtypes/structure round-trip exactly.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import OptState

_SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state: Optional[OptState] = None,
                    step: int = 0, meta: Optional[dict] = None) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    arrays = {f"params{_SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        arrays.update({f"opt_m{_SEP}{k}": v
                       for k, v in _flatten(opt_state.m).items()})
        arrays.update({f"opt_v{_SEP}{k}": v
                       for k, v in _flatten(opt_state.v).items()})
        arrays["opt_step"] = np.asarray(opt_state.step)
    arrays["__step__"] = np.asarray(step)
    np.savez(p, **arrays)
    if meta:
        p.with_suffix(".meta.json").write_text(json.dumps(meta, default=str))


def _unflatten_into(template, flat: dict, prefix: str):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = prefix + _SEP + _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def load_checkpoint(path: str, params_template,
                    opt_template: Optional[OptState] = None,
                    ) -> Tuple[Any, Optional[OptState], int]:
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    params = _unflatten_into(params_template, flat, "params")
    opt = None
    if opt_template is not None and "opt_step" in flat:
        opt = OptState(
            step=jnp.asarray(flat["opt_step"]),
            m=_unflatten_into(opt_template.m, flat, "opt_m"),
            v=_unflatten_into(opt_template.v, flat, "opt_v"))
    return params, opt, int(flat["__step__"])
