"""Discrete-event simulator over profiled throughput curves.

Two roles (DESIGN.md §2, §7.1):

1. It models the *spatial* concurrency semantics the paper has on GPU —
   decode and prefill genuinely concurrent on disjoint partitions —
   which a single CPU/TPU core can only time-multiplex.  Service rates
   come from a measured ``ThroughputProfile``, so simulated seconds are
   grounded in real engine timings.
2. It provides the empirical side of the competitive-ratio validation:
   run AgentServe's controller trace through the simulator, compare its
   prefill service with the offline optimum (competitive.offline_optimum)
   and check Theorem 1's bound.

The simulator advances in control intervals Δt.  Per interval, decode
work r·Δt·μ_D(R)/r... — rates are read off the profile at the current
allocation; queues drain accordingly; TPOT is 1/per-stream decode rate.

Policy semantics come from the **same ``CyclePlanner`` objects the real
engine executes** (DESIGN.md §9) — whether the Algorithm-1 controller
runs, the static partition for non-adaptive policies, and the prefill
service order (phase split / FCFS / SLO classes) are all read off the
planner, so the engine and the simulator cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.competitive import ThroughputProfile
from repro.core.planner import CyclePlanner
from repro.core.scheduler import SchedulerConfig, TPOTScheduler
from repro.serving.policies import make_planner


@dataclasses.dataclass
class SimSession:
    cold_len: int
    turns: List[dict]                # {resume_len, decode_len, tool_s}
    arrival_s: float = 0.0
    slo_class: str = "batch"         # interactive | batch (priority)
    # state
    phase: str = "cold"              # cold | resume | decode | tool | done
    turn_idx: int = 0
    work_left: float = 0.0
    ready_s: float = 0.0
    ttfts: List[float] = dataclasses.field(default_factory=list)
    req_arrival: float = 0.0
    tpots: List[float] = dataclasses.field(default_factory=list)
    # fractional decode tokens carried between intervals: slow streams
    # producing <0.5 tok per dt must still accumulate TPOT samples
    tpot_credit: float = 0.0

    def emit_tpots(self, produced: float, per_stream: float,
                   final: bool = False) -> None:
        """Accumulate ``produced`` decoded tokens and record one TPOT
        sample per *whole* token crossed (fractional remainder carries
        to the next interval; ``final`` flushes it at burst end)."""
        self.tpot_credit += produced
        n = int(self.tpot_credit)
        if final:
            n = int(round(self.tpot_credit))
        if n > 0:
            self.tpots.extend([1.0 / max(per_stream, 1e-9)] * n)
            self.tpot_credit -= n
        if final:
            self.tpot_credit = 0.0


def sessions_from_workload(ws, time_origin: float = 0.0) -> List[SimSession]:
    out = []
    for s in ws:
        turns = [dict(resume_len=len(t.prefill_tokens),
                      decode_len=t.decode_len, tool_s=t.tool_latency_s)
                 for t in s.turns[1:]]
        out.append(SimSession(
            cold_len=len(s.turns[0].prefill_tokens),
            turns=[dict(resume_len=0,
                        decode_len=s.turns[0].decode_len,
                        tool_s=s.turns[0].tool_latency_s)] + turns,
            arrival_s=s.ready_s,
            slo_class=getattr(s, "slo_class", "batch")))
    return out


@dataclasses.dataclass
class SimResult:
    ttfts: List[float]
    tpots: List[float]
    prefill_tokens_served: float
    wall_s: float
    r_alloc_trace: List[float]
    eta_trace: List[float]           # cold fraction per interval (Eq. 1)

    def summary(self) -> Dict[str, float]:
        return dict(
            ttft_p50=float(np.percentile(self.ttfts, 50)) if self.ttfts else np.nan,
            ttft_p95=float(np.percentile(self.ttfts, 95)) if self.ttfts else np.nan,
            tpot_p50=float(np.percentile(self.tpots, 50)) if self.tpots else np.nan,
            tpot_p95=float(np.percentile(self.tpots, 95)) if self.tpots else np.nan,
            prefill_tokens=self.prefill_tokens_served,
        )


def simulate(profile: ThroughputProfile, sessions: Sequence[SimSession], *,
             planner: Union[CyclePlanner, str] = "agentserve",
             tpot_slo_ms: float = 50.0, dt: float = 0.05,
             static_r_frac: Optional[float] = None,
             eps_ctx: float = 0.0, max_t: float = 300.0) -> SimResult:
    """Spatial-concurrency simulation.  Decode holds R(t) of S; prefill
    holds S - R(t) *simultaneously* (the GPU Green-Context semantics).

    ``planner`` is the same ``CyclePlanner`` the engine would execute
    (or a registered policy name); ``static_r_frac`` overrides the
    spec's static partition for non-adaptive sweeps."""
    planner = make_planner(planner)
    S = float(profile.levels[-1])
    g = float(profile.levels[0])
    sched = TPOTScheduler(SchedulerConfig(
        total_resources=int(S), r_base=int(g), r_init=int(2 * g),
        delta_r=int(g), tpot_slo_ms=tpot_slo_ms, control_interval_s=dt))
    adaptive = planner.adaptive
    if not adaptive:
        frac = (planner.spec.static_r_frac if static_r_frac is None
                else static_r_frac)
        sched.state.r_min = int(frac * S)

    t = 0.0
    prefill_served = 0.0
    r_trace, eta_trace = [], []
    sess = list(sessions)
    while t < max_t and any(s.phase != "done" for s in sess):
        # arrivals / tool completions
        for s in sess:
            if s.phase == "cold" and s.arrival_s <= t and s.work_left == 0:
                s.work_left = s.cold_len
                s.req_arrival = t
            if s.phase == "tool" and s.ready_s <= t:
                s.phase = "resume"
                s.work_left = s.turns[s.turn_idx]["resume_len"]
                s.req_arrival = t
                if s.work_left == 0:
                    s.phase = "decode"
                    s.work_left = s.turns[s.turn_idx]["decode_len"]
                    s.ttfts.append(0.0)

        R = sched.state.r_min
        r_trace.append(R)
        Rp = S - R

        cold_q = [s for s in sess if s.phase == "cold" and s.arrival_s <= t]
        res_q = [s for s in sess if s.phase == "resume"]
        dec_q = [s for s in sess if s.phase == "decode"]

        cold_work = sum(s.work_left for s in cold_q)
        res_work = sum(s.work_left for s in res_q)
        eta = cold_work / max(cold_work + res_work, 1e-9)
        eta_trace.append(eta)

        # ---- decode partition ----------------------------------------
        if dec_q:
            rate = profile.mu_d(R) * (1.0 - eps_ctx)      # tokens/s total
            per_stream = rate / len(dec_q)
            # TPOT_step = ΔL/ΔK with ΔK decode *rounds* in this interval
            rounds = rate * dt / len(dec_q)
            sched.record_decode_step(dt, steps=max(rounds, 1e-9))
            for s in dec_q:
                produced = per_stream * dt
                done = produced >= s.work_left
                s.emit_tpots(min(produced, s.work_left), per_stream,
                             final=done)
                s.work_left -= produced
                if s.work_left <= 0:
                    s.turn_idx += 1
                    if s.turn_idx >= len(s.turns):
                        s.phase = "done"
                    else:
                        s.phase = "tool"
                        s.ready_s = t + s.turns[s.turn_idx - 1]["tool_s"]

        # ---- prefill partition (concurrent!) --------------------------
        # service order is the planner's call (phase split / FCFS / SLO)
        order = planner.sim_prefill_order(
            res_q, cold_q, arrival=lambda s: s.req_arrival,
            slo=lambda s: s.slo_class)
        cold_set = set(map(id, cold_q))
        time_left = (1.0 - eps_ctx) * dt
        for s in order:
            if time_left <= 0:
                break
            mu = profile.mu_p(Rp, 1.0 if id(s) in cold_set else 0.0)
            can = mu * time_left
            use = min(can, s.work_left)
            prefill_served += use
            time_left -= use / max(mu, 1e-9)
            s.work_left -= use
            if s.work_left <= 0:
                s.ttfts.append(t + dt - s.req_arrival)
                s.phase = "decode"
                s.work_left = s.turns[s.turn_idx]["decode_len"]

        if adaptive:
            sched.update()
        t += dt

    all_ttft = [x for s in sess for x in s.ttfts]
    all_tpot = [x for s in sess for x in s.tpots]
    return SimResult(all_ttft, all_tpot, prefill_served, t, r_trace,
                     eta_trace)
