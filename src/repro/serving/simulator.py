"""Discrete-event simulator over profiled throughput curves.

Two roles (DESIGN.md §2, §7.1):

1. It models the *spatial* concurrency semantics the paper has on GPU —
   decode and prefill genuinely concurrent on disjoint partitions —
   which a single CPU/TPU core can only time-multiplex.  Service rates
   come from a measured ``ThroughputProfile``, so simulated seconds are
   grounded in real engine timings.
2. It provides the empirical side of the competitive-ratio validation:
   run AgentServe's controller trace through the simulator, compare its
   prefill service with the offline optimum (competitive.offline_optimum)
   and check Theorem 1's bound.

The simulator advances in control intervals Δt.  Per interval, decode
work r·Δt·μ_D(R)/r... — rates are read off the profile at the current
allocation; queues drain accordingly; TPOT is 1/per-stream decode rate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.competitive import ThroughputProfile
from repro.core.scheduler import SchedulerConfig, TPOTScheduler


@dataclasses.dataclass
class SimSession:
    cold_len: int
    turns: List[dict]                # {resume_len, decode_len, tool_s}
    arrival_s: float = 0.0
    # state
    phase: str = "cold"              # cold | resume | decode | tool | done
    turn_idx: int = 0
    work_left: float = 0.0
    ready_s: float = 0.0
    ttfts: List[float] = dataclasses.field(default_factory=list)
    req_arrival: float = 0.0
    tpots: List[float] = dataclasses.field(default_factory=list)


def sessions_from_workload(ws, time_origin: float = 0.0) -> List[SimSession]:
    out = []
    for s in ws:
        turns = [dict(resume_len=len(t.prefill_tokens),
                      decode_len=t.decode_len, tool_s=t.tool_latency_s)
                 for t in s.turns[1:]]
        out.append(SimSession(
            cold_len=len(s.turns[0].prefill_tokens),
            turns=[dict(resume_len=0,
                        decode_len=s.turns[0].decode_len,
                        tool_s=s.turns[0].tool_latency_s)] + turns,
            arrival_s=s.ready_s))
    return out


@dataclasses.dataclass
class SimResult:
    ttfts: List[float]
    tpots: List[float]
    prefill_tokens_served: float
    wall_s: float
    r_alloc_trace: List[float]
    eta_trace: List[float]           # cold fraction per interval (Eq. 1)

    def summary(self) -> Dict[str, float]:
        return dict(
            ttft_p50=float(np.percentile(self.ttfts, 50)) if self.ttfts else np.nan,
            ttft_p95=float(np.percentile(self.ttfts, 95)) if self.ttfts else np.nan,
            tpot_p50=float(np.percentile(self.tpots, 50)) if self.tpots else np.nan,
            tpot_p95=float(np.percentile(self.tpots, 95)) if self.tpots else np.nan,
            prefill_tokens=self.prefill_tokens_served,
        )


def simulate(profile: ThroughputProfile, sessions: Sequence[SimSession], *,
             policy: str = "agentserve", tpot_slo_ms: float = 50.0,
             dt: float = 0.05, static_r_frac: float = 0.5,
             eps_ctx: float = 0.0, max_t: float = 300.0) -> SimResult:
    """Spatial-concurrency simulation.  Decode holds R(t) of S; prefill
    holds S - R(t) *simultaneously* (the GPU Green-Context semantics)."""
    S = float(profile.levels[-1])
    g = float(profile.levels[0])
    sched = TPOTScheduler(SchedulerConfig(
        total_resources=int(S), r_base=int(g), r_init=int(2 * g),
        delta_r=int(g), tpot_slo_ms=tpot_slo_ms, control_interval_s=dt))
    adaptive = policy in ("agentserve",)
    split = policy in ("agentserve", "pd_static")
    if not adaptive:
        sched.state.r_min = int(static_r_frac * S)

    t = 0.0
    prefill_served = 0.0
    r_trace, eta_trace = [], []
    sess = list(sessions)
    while t < max_t and any(s.phase != "done" for s in sess):
        # arrivals / tool completions
        for s in sess:
            if s.phase == "cold" and s.arrival_s <= t and s.work_left == 0:
                s.work_left = s.cold_len
                s.req_arrival = t
            if s.phase == "tool" and s.ready_s <= t:
                s.phase = "resume"
                s.work_left = s.turns[s.turn_idx]["resume_len"]
                s.req_arrival = t
                if s.work_left == 0:
                    s.phase = "decode"
                    s.work_left = s.turns[s.turn_idx]["decode_len"]
                    s.ttfts.append(0.0)

        R = sched.state.r_min
        r_trace.append(R)
        Rp = S - R

        cold_q = [s for s in sess if s.phase == "cold" and s.arrival_s <= t]
        res_q = [s for s in sess if s.phase == "resume"]
        dec_q = [s for s in sess if s.phase == "decode"]

        cold_work = sum(s.work_left for s in cold_q)
        res_work = sum(s.work_left for s in res_q)
        eta = cold_work / max(cold_work + res_work, 1e-9)
        eta_trace.append(eta)

        # ---- decode partition ----------------------------------------
        if dec_q:
            rate = profile.mu_d(R) * (1.0 - eps_ctx)      # tokens/s total
            per_stream = rate / len(dec_q)
            # TPOT_step = ΔL/ΔK with ΔK decode *rounds* in this interval
            rounds = rate * dt / len(dec_q)
            sched.record_decode_step(dt, steps=max(rounds, 1e-9))
            for s in dec_q:
                produced = per_stream * dt
                s.tpots.extend([1.0 / max(per_stream, 1e-9)]
                               * int(round(min(produced, s.work_left))))
                s.work_left -= produced
                if s.work_left <= 0:
                    s.turn_idx += 1
                    if s.turn_idx >= len(s.turns):
                        s.phase = "done"
                    else:
                        s.phase = "tool"
                        s.ready_s = t + s.turns[s.turn_idx - 1]["tool_s"]

        # ---- prefill partition (concurrent!) --------------------------
        # resume prefills first if the policy splits phases
        order = (res_q + cold_q) if split else sorted(
            res_q + cold_q, key=lambda s: s.req_arrival)
        time_left = (1.0 - eps_ctx) * dt
        for s in order:
            if time_left <= 0:
                break
            mu = profile.mu_p(Rp, 1.0 if s in cold_q else 0.0)
            can = mu * time_left
            use = min(can, s.work_left)
            prefill_served += use
            time_left -= use / max(mu, 1e-9)
            s.work_left -= use
            if s.work_left <= 0:
                s.ttfts.append(t + dt - s.req_arrival)
                s.phase = "decode"
                s.work_left = s.turns[s.turn_idx]["decode_len"]

        if adaptive:
            sched.update()
        t += dt

    all_ttft = [x for s in sess for x in s.ttfts]
    all_tpot = [x for s in sess for x in s.tpots]
    return SimResult(all_ttft, all_tpot, prefill_served, t, r_trace,
                     eta_trace)
