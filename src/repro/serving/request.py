"""Request/session dataclasses for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class SessionState(enum.Enum):
    WAITING_PREFILL = "waiting_prefill"   # request submitted, not started
    PREFILLING = "prefilling"             # chunks in flight
    PREFILL_PAUSED = "prefill_paused"     # cold prefill preempted by an
    #                                       interactive-class arrival: KV
    #                                       parked on device, slot freed
    DECODING = "decoding"
    TOOL_CALL = "tool_call"               # engine-clocked tool wait
    TOOL_WAIT = "tool_wait"               # gateway-clocked tool wait:
    #                                       resume_session() re-arms it
    FINISHED = "finished"
    ABORTED = "aborted"                   # terminal: fault / deadline /
    #                                       disconnect (abort_reason says)


@dataclasses.dataclass
class AgentTurn:
    """One reasoning-action step: a prefill (cold or resume) followed by a
    bounded decode burst and an external tool call."""
    prefill_tokens: np.ndarray        # tokens to append
    decode_len: int                   # structured-output length
    tool_latency_s: float             # simulated external-call duration


@dataclasses.dataclass
class Session:
    session_id: int
    turns: List[AgentTurn]
    workload: str = "react"           # react | plan_execute
    shared_prefix_len: int = 0        # leading tokens shared across sessions
    external_tools: bool = False      # gateway owns the tool-wait clock
    slo_class: str = "batch"          # interactive | batch (PriorityPlanner)
    # runtime state
    state: SessionState = SessionState.WAITING_PREFILL
    turn_idx: int = 0
    slot: int = -1                    # KV-cache slot
    cached_len: int = 0               # tokens in KV cache
    prefill_done: int = 0             # tokens of current turn prefilled
    decoded: int = 0                  # tokens decoded in current turn
    last_token: int = 0
    arrival_s: float = 0.0            # current request submission time
    ready_s: float = 0.0              # when the session may next be served
    deadline_s: float = float("inf")  # absolute engine-clock SLO deadline:
    #                                   the engine aborts the session past
    #                                   it (the gateway sets it at submit)
    abort_reason: Optional[str] = None  # terminal fault attribution
    # metrics bookkeeping
    request_arrivals: List[float] = dataclasses.field(default_factory=list)
    first_token_s: List[float] = dataclasses.field(default_factory=list)
    token_times_s: List[float] = dataclasses.field(default_factory=list)
    # per-request admission wait (request ready -> admitted), aligned
    # with request_arrivals — the open-loop queue-delay breakdown
    queue_delays_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def current_turn(self) -> Optional[AgentTurn]:
        return self.turns[self.turn_idx] if self.turn_idx < len(self.turns) else None

    @property
    def remaining_prefill(self) -> int:
        t = self.current_turn
        return 0 if t is None else len(t.prefill_tokens) - self.prefill_done

    @property
    def total_prompt_len(self) -> int:
        t = self.current_turn
        return self.cached_len + (len(t.prefill_tokens) if t else 0)

    def output_tokens(self) -> int:
        return len(self.token_times_s)
