"""Unified telemetry layer: metrics registry, span tracing, timeline
export (DESIGN.md §11).

Three coordinated pieces, all allocation-light on the serving hot path:

  * **MetricsRegistry** — typed counters / gauges / histograms.  One
    registry per engine; the gateway registers its own metrics into the
    engine's registry at construction, so ``engine.stats()``,
    ``gateway.stats()`` and the HTTP ``GET /stats`` / ``GET /metrics``
    surfaces are all *views of the same object* — stats keys cannot
    drift between them (the PR-6 fault counters did exactly that).
    ``snapshot()`` flattens to the ``Dict[str, float]`` the existing
    ``stats()`` contract expects; ``prometheus_text()`` renders the
    text exposition format.  ``RegistryDict`` lets legacy dict-shaped
    counter groups (``engine.hotpath_stats``, ``gateway.counters``)
    keep their ``stats["x"] += 1`` call sites while every increment
    lands in a registered metric.

  * **SpanTracer** — per-session span timelines (QUEUED → PREFILL →
    DECODE → TOOL_WAIT → RESUME → DONE/ABORTED, plus per-tool-attempt
    child spans), per-slot occupancy spans, and per-cycle spans
    carrying the executed ``CyclePlan`` id.  Spans are plain tuples in
    bounded deques; recording happens only at phase boundaries and the
    engine's sampled flush cadence, never per token.

  * **Timeline export** — ``export_trace()`` renders the tracer's rings
    as Chrome/Perfetto ``trace_event`` JSON: one track per session, one
    per KV slot, one cycle/plan track.  Cycle spans carry the plan id
    recorded in the engine's ``PlanJournal``, so a journal replay's
    timeline can be diffed against the original run's.
    ``validate_trace_events`` / ``parse_prometheus_text`` are the
    self-contained format checkers the CI telemetry smoke uses (run
    ``python -m repro.serving.telemetry trace.json`` to validate a
    dumped trace).

Timestamps are engine-clock seconds (``ServingEngine.clock()``)
throughout, so spans, the cycle trace and the plan journal share one
timebase.
"""
from __future__ import annotations

import collections
import json
import math
import re
import sys
from typing import Callable, Dict, Iterable, List, Mapping, Optional, \
    Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RegistryDict",
    "SpanTracer", "Telemetry", "export_trace", "validate_trace_events",
    "parse_prometheus_text", "reconstruct_latency",
]

# default histogram buckets (seconds): sub-ms dispatch gaps up to
# multi-second queue waits
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


class Counter:
    """Monotonic (by convention) numeric metric.  ``value`` is plain
    attribute access so ``RegistryDict`` increments stay cheap."""
    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value: either set explicitly or computed by a
    callback at read time (queue depths, occupancy, KV pressure)."""
    kind = "gauge"
    __slots__ = ("name", "help", "value", "fn")

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name, self.help, self.value, self.fn = name, help, 0.0, fn

    def set(self, v: float) -> None:
        self.value = v

    def read(self) -> float:
        return float(self.fn()) if self.fn is not None else float(self.value)


class Histogram:
    """Fixed-bucket histogram plus a bounded raw-sample ring for
    accurate percentiles (bucket interpolation is too coarse for the
    sub-ms dispatch-gap distribution the ROADMAP item needs)."""
    kind = "histogram"
    __slots__ = ("name", "help", "buckets", "counts", "total", "sum",
                 "samples")

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 sample_cap: int = 8192):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * len(self.buckets)
        self.total = 0
        self.sum = 0.0
        self.samples: collections.deque = collections.deque(
            maxlen=sample_cap)

    def observe(self, v: float, count: int = 1) -> None:
        """Record ``count`` observations of value ``v`` (the engine's
        sampled flush observes one window-mean gap for all n steps at
        once — one call, not n)."""
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += count
                break
        self.total += count
        self.sum += v * count
        self.samples.append(v)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        k = min(len(xs) - 1, max(0, int(round((p / 100.0) * (len(xs) - 1)))))
        return xs[k]


class MetricsRegistry:
    """One flat namespace of typed metrics.  ``counter``/``gauge``/
    ``histogram`` are get-or-create (the gateway and engine register
    independently; re-registering the same name with the same kind
    returns the existing metric, a different kind is a hard error)."""

    def __init__(self):
        self._metrics: "collections.OrderedDict[str, object]" = \
            collections.OrderedDict()

    def _get_or_create(self, cls, name: str, **kwargs):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m
        m = cls(name, **kwargs)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help=help)

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get_or_create(Gauge, name, help=help)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help=help,
                                   buckets=buckets)

    def metrics(self) -> List[object]:
        return list(self._metrics.values())

    # ---- the stats() surface ------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flatten every metric to the ``Dict[str, float]`` shape the
        existing ``stats()`` consumers (tests, /stats JSON) expect.
        Histograms contribute ``_count``/``_sum`` plus raw-sample
        percentiles (0.0 when empty — the JSON surface must stay
        NaN-free)."""
        out: Dict[str, float] = {}
        for m in self._metrics.values():
            if isinstance(m, Counter):
                out[m.name] = float(m.value)
            elif isinstance(m, Gauge):
                out[m.name] = m.read()
            else:
                out[f"{m.name}_count"] = float(m.total)
                out[f"{m.name}_sum"] = float(m.sum)
                for p in (50, 95, 99):
                    out[f"{m.name}_p{p}"] = float(m.percentile(p))
        return out

    # ---- the /metrics surface -----------------------------------------
    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4): # HELP /
        # TYPE headers, cumulative ``_bucket{le=...}`` histogram series
        with the mandatory ``+Inf`` bucket, ``_sum`` and ``_count``."""
        lines: List[str] = []
        for m in self._metrics.values():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Counter):
                lines.append(f"{m.name} {float(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"{m.name} {m.read()}")
            else:
                acc = 0
                for b, c in zip(m.buckets, m.counts):
                    acc += c
                    lines.append(f'{m.name}_bucket{{le="{b}"}} {acc}')
                lines.append(f'{m.name}_bucket{{le="+Inf"}} {m.total}')
                lines.append(f"{m.name}_sum {float(m.sum)}")
                lines.append(f"{m.name}_count {m.total}")
        return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Validating parser for the text exposition format (the CI smoke's
    scrape check).  Returns ``{sample_name{labels}: value}``; raises
    ``ValueError`` on malformed lines, unknown TYPEs, samples preceding
    their TYPE header, or non-monotonic histogram buckets."""
    samples: Dict[str, float] = {}
    types: Dict[str, str] = {}
    bucket_last: Dict[str, float] = {}
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"line {ln}: malformed comment {raw!r}")
            if parts[1] == "TYPE":
                typ = parts[3] if len(parts) > 3 else ""
                if typ not in ("counter", "gauge", "histogram", "summary",
                               "untyped"):
                    raise ValueError(f"line {ln}: unknown type {raw!r}")
                types[parts[2]] = typ
            continue
        mobj = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
                        r"(\{[^}]*\})?\s+(\S+)(?:\s+\d+)?$", line)
        if mobj is None:
            raise ValueError(f"line {ln}: malformed sample {raw!r}")
        name, labels, val = mobj.group(1), mobj.group(2) or "", mobj.group(3)
        try:
            fval = float(val)
        except ValueError:
            raise ValueError(f"line {ln}: bad value {val!r}") from None
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        if family not in types and name not in types:
            raise ValueError(f"line {ln}: sample {name!r} has no TYPE")
        if name.endswith("_bucket"):
            lm = re.match(r'\{le="([^"]+)"\}', labels)
            if lm is None:
                raise ValueError(f"line {ln}: bucket without le label")
            if fval < bucket_last.get(name, 0.0):
                raise ValueError(
                    f"line {ln}: non-cumulative histogram bucket")
            bucket_last[name] = fval
        samples[name + labels] = fval
    return samples


class RegistryDict(collections.abc.MutableMapping):
    """Dict-shaped facade over registered counters.

    ``engine.hotpath_stats`` and ``gateway.counters`` predate the
    registry and are written as plain dicts all over the engine, the
    gateway and the tests (``stats["kv_deferred"] += 1``).  This keeps
    that call-site syntax while making the registry the single source
    of truth.  ``rename`` maps a dict key to a different *registry*
    name where the flat namespace would collide (the engine's
    ``aborted`` vs the gateway's ``aborted``)."""

    def __init__(self, registry: MetricsRegistry,
                 initial: Mapping[str, float],
                 rename: Optional[Mapping[str, str]] = None,
                 help_prefix: str = ""):
        self._metrics: "collections.OrderedDict[str, Counter]" = \
            collections.OrderedDict()
        rename = rename or {}
        for key, val in initial.items():
            c = registry.counter(rename.get(key, key),
                                 help=f"{help_prefix}{key}")
            c.value = val
            self._metrics[key] = c

    def __getitem__(self, key):
        return self._metrics[key].value

    def __setitem__(self, key, value):
        self._metrics[key].value = value

    def __delitem__(self, key):
        raise TypeError("RegistryDict keys are fixed at construction")

    def __iter__(self):
        return iter(self._metrics)

    def __len__(self):
        return len(self._metrics)


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

# span tuple layout: (track, track_id, name, t0, t1, args)
SESSION_TRACK = "session"
SLOT_TRACK = "slot"
CYCLE_TRACK = "cycle"

TERMINAL_PHASES = ("DONE", "ABORTED")


class SpanTracer:
    """Bounded span recorder.  Open session spans live in one small
    dict (one entry per live session); completed spans append tuples to
    a bounded ring.  All methods are O(1) and run only at phase
    boundaries / flush points — never per decoded token."""

    def __init__(self, spans_max: int = 200_000):
        self.spans: collections.deque = collections.deque(maxlen=spans_max)
        self._open: Dict[int, List] = {}          # sid -> [phase, t0, args]
        self._open_slots: Dict[int, Tuple[int, float]] = {}  # slot->(sid,t0)

    def reset(self) -> None:
        self.spans.clear()
        self._open.clear()
        self._open_slots.clear()

    # ---- session timeline ---------------------------------------------
    def transition(self, sid: int, phase: str, t: float, **args) -> None:
        """Close ``sid``'s current span at ``t`` and open the next one.
        Terminal phases (DONE/ABORTED) close the timeline: they record
        a zero-length terminal marker span instead of staying open, so
        ``open_span_count`` reaching zero *is* the no-leak invariant."""
        cur = self._open.pop(sid, None)
        if cur is not None:
            self.spans.append(
                (SESSION_TRACK, sid, cur[0], cur[1], t, cur[2]))
        if phase in TERMINAL_PHASES:
            self.spans.append(
                (SESSION_TRACK, sid, phase, t, t, args or None))
        else:
            self._open[sid] = [phase, t, args or None]

    def child(self, sid: int, name: str, t0: float, t1: float,
              **args) -> None:
        """Record a completed child span on a session's track (tool
        attempts, retries) — it nests under the open TOOL_WAIT span."""
        self.spans.append((SESSION_TRACK, sid, name, t0, t1, args or None))

    # ---- slot occupancy -----------------------------------------------
    def slot_bind(self, slot: int, sid: int, t: float) -> None:
        prev = self._open_slots.pop(slot, None)
        if prev is not None:             # defensive: close a stale bind
            self.spans.append((SLOT_TRACK, slot, f"sid {prev[0]}",
                               prev[1], t, {"session": prev[0]}))
        self._open_slots[slot] = (sid, t)

    def slot_free(self, slot: int, t: float) -> None:
        prev = self._open_slots.pop(slot, None)
        if prev is not None:
            self.spans.append((SLOT_TRACK, slot, f"sid {prev[0]}",
                               prev[1], t, {"session": prev[0]}))

    # ---- cycle/plan track ---------------------------------------------
    def cycle(self, plan_id: int, kind: str, t0: float, t1: float,
              **args) -> None:
        args["plan_id"] = plan_id
        self.spans.append((CYCLE_TRACK, 0, kind, t0, t1, args))

    # ---- leak accounting ----------------------------------------------
    def open_spans(self) -> Dict[str, List[int]]:
        return {"sessions": sorted(self._open),
                "slots": sorted(self._open_slots)}

    def open_span_count(self) -> int:
        return len(self._open) + len(self._open_slots)


class Telemetry:
    """Engine-owned facade: the registry is always live (it *is* the
    stats surface); the tracer exists only when tracing is enabled, so
    ``telemetry=off`` engines skip every span call via one None
    check."""

    def __init__(self, enabled: bool = True, spans_max: int = 200_000,
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry or MetricsRegistry()
        self.enabled = enabled
        self.tracer: Optional[SpanTracer] = (
            SpanTracer(spans_max) if enabled else None)

    def export_trace(self, path: str) -> int:
        if self.tracer is None:
            raise RuntimeError(
                "trace export requires telemetry=on (EngineConfig)")
        doc = export_trace(self.tracer)
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])


# ---------------------------------------------------------------------------
# Chrome/Perfetto trace_event export
# ---------------------------------------------------------------------------

_PID = {SESSION_TRACK: 1, SLOT_TRACK: 2, CYCLE_TRACK: 3}


def export_trace(tracer: SpanTracer) -> Dict:
    """Render the tracer's rings as a Chrome ``trace_event`` JSON
    object (Perfetto/chrome://tracing loadable): 'X' complete events
    with µs timestamps, one process per track family (sessions, KV
    slots, engine cycles), one thread per session / slot."""
    events: List[Dict] = []
    for pid, name in ((1, "sessions"), (2, "kv slots"), (3, "engine")):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": name}})
    named_tids = set()
    spans = list(tracer.spans)
    for track, tid, name, t0, t1, args in spans:
        pid = _PID[track]
        if (pid, tid) not in named_tids:
            named_tids.add((pid, tid))
            label = {SESSION_TRACK: f"session {tid}",
                     SLOT_TRACK: f"slot {tid}",
                     CYCLE_TRACK: "cycles"}[track]
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": label}})
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
              "ts": t0 * 1e6, "dur": max(0.0, (t1 - t0) * 1e6)}
        if args:
            ev["args"] = args
        events.append(ev)
    # still-open spans export as 'B' begin events so a mid-run dump of
    # a live server is loadable too
    for sid, (phase, t0, args) in tracer._open.items():
        ev = {"ph": "B", "pid": 1, "tid": sid, "name": phase,
              "ts": t0 * 1e6}
        if args:
            ev["args"] = args
        events.append(ev)
    for slot, (sid, t0) in tracer._open_slots.items():
        events.append({"ph": "B", "pid": 2, "tid": slot,
                       "name": f"sid {sid}", "ts": t0 * 1e6,
                       "args": {"session": sid}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_trace_events(doc) -> int:
    """Structural validation of a ``trace_event`` JSON document (the CI
    telemetry smoke's schema check).  Returns the event count; raises
    ``ValueError`` with the first offending event otherwise."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "M", "i", "C"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                raise ValueError(f"event {i}: {key} must be an int")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"event {i}: missing name")
        if ph in ("X", "B", "E", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or math.isnan(ts):
                raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float)) or math.isnan(dur)
                    or dur < 0):
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: args must be an object")
    return len(events)


# ---------------------------------------------------------------------------
# latency reconstruction from spans (the acceptance cross-check)
# ---------------------------------------------------------------------------

def reconstruct_latency(spans: Iterable[Tuple],
                        ) -> Tuple[List[float], float]:
    """Recover (per-request TTFTs, mean TPOT) from a span stream, for
    sessions whose timeline reached DONE.

    TTFT: each DECODE span starts at its burst's first-token timestamp
    and the PREFILL/RESUME span it closes started at the request's
    submission — exactly ``metrics.collect_ttfts``'s operands.  TPOT:
    within a burst the interpolated inter-token gaps telescope, so
    ``sum(decode span durations) / sum(tokens - 1)`` equals the mean of
    ``metrics.collect_tpots`` exactly.  The 1%-agreement acceptance
    check (tests + serve smoke) runs through this function."""
    pending: Dict[int, float] = {}       # sid -> open request start
    ttfts: Dict[int, List[float]] = collections.defaultdict(list)
    gap_sum: Dict[int, float] = collections.defaultdict(float)
    gap_n: Dict[int, int] = collections.defaultdict(int)
    done: set = set()
    for track, sid, name, t0, t1, args in spans:
        if track != SESSION_TRACK:
            continue
        if name in ("PREFILL", "RESUME"):
            if not (args or {}).get("resumed"):
                pending[sid] = t0        # resumed=True continues a
                #                          request, it starts none
        elif name == "DECODE":
            start = pending.pop(sid, None)
            if start is not None:
                ttfts[sid].append(t0 - start)
            tokens = int((args or {}).get("tokens", 1))
            gap_sum[sid] += t1 - t0
            gap_n[sid] += max(0, tokens - 1)
        elif name == "DONE":
            done.add(sid)
    flat_ttfts = [t for sid in sorted(done) for t in ttfts[sid]]
    total_gap = sum(gap_sum[sid] for sid in done)
    total_n = sum(gap_n[sid] for sid in done)
    mean_tpot = total_gap / total_n if total_n else float("nan")
    return flat_ttfts, mean_tpot


# ---------------------------------------------------------------------------
# CLI: validate a dumped trace (CI telemetry smoke)
# ---------------------------------------------------------------------------

def _main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.serving.telemetry TRACE.json "
              "[METRICS.txt]", file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    n = validate_trace_events(doc)
    x = sum(1 for ev in doc["traceEvents"] if ev.get("ph") == "X")
    print(f"{argv[0]}: OK — {n} trace events ({x} complete spans)")
    if len(argv) > 1:
        with open(argv[1]) as f:
            samples = parse_prometheus_text(f.read())
        print(f"{argv[1]}: OK — {len(samples)} prometheus samples")
    return 0


if __name__ == "__main__":
    sys.exit(_main())
