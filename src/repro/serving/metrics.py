"""Serving metrics: TTFT, TPOT, throughput, session-level SLO attainment.

Definitions follow the paper §IV-A exactly:
  TTFT  — request submission -> first output token (per request: the
          cold prefill and every resume prefill each start a request).
  TPOT  — inter-token latency within decode bursts.
  throughput — aggregate output tokens / wall time.
  SLO attainment — fraction of *sessions* whose every request met the
          TTFT bound AND whose TPOT stayed within the TPOT bound
          (joint criterion; we use per-session max TTFT and p95 TPOT).
Thresholds are calibrated per model-device pair by scaling isolated
(single-session, unloaded) performance by a constant factor, as §IV-A
prescribes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Session


def _pct(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else float("nan")


@dataclasses.dataclass
class SLOThresholds:
    ttft_s: float
    tpot_s: float

    @classmethod
    def from_isolated(cls, isolated_ttft_s: float, isolated_tpot_s: float,
                      factor: float = 3.0) -> "SLOThresholds":
        return cls(ttft_s=isolated_ttft_s * factor,
                   tpot_s=isolated_tpot_s * factor)


@dataclasses.dataclass
class ServingReport:
    policy: str
    num_sessions: int
    wall_time_s: float
    ttft_p50_s: float
    ttft_p95_s: float
    tpot_p50_s: float
    tpot_p95_s: float
    throughput_tok_s: float
    slo_attainment: float
    total_output_tokens: int
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)

    def row(self) -> str:
        return (f"{self.policy},{self.num_sessions},{self.wall_time_s:.3f},"
                f"{self.ttft_p50_s * 1e3:.1f},{self.ttft_p95_s * 1e3:.1f},"
                f"{self.tpot_p50_s * 1e3:.1f},{self.tpot_p95_s * 1e3:.1f},"
                f"{self.throughput_tok_s:.1f},{self.slo_attainment:.3f}")

    HEADER = ("policy,sessions,wall_s,ttft_p50_ms,ttft_p95_ms,"
              "tpot_p50_ms,tpot_p95_ms,throughput_tok_s,slo_rate")


def collect_ttfts(sessions: Sequence[Session]) -> List[float]:
    out = []
    for s in sessions:
        for arr, first in zip(s.request_arrivals, s.first_token_s):
            out.append(first - arr)
    return out


def collect_tpots(sessions: Sequence[Session]) -> List[float]:
    """Inter-token gaps within each contiguous decode burst."""
    out = []
    for s in sessions:
        ts = np.asarray(s.token_times_s)
        firsts = set(np.round(s.first_token_s, 9).tolist())
        gaps = np.diff(ts)
        for i, g in enumerate(gaps):
            # a gap that ends on a burst-first token spans a tool call /
            # prefill; exclude it from TPOT
            if round(float(ts[i + 1]), 9) not in firsts:
                out.append(float(g))
    return out


def session_slo_ok(s: Session, thr: SLOThresholds) -> bool:
    ttfts = [f - a for a, f in zip(s.request_arrivals, s.first_token_s)]
    if any(t > thr.ttft_s for t in ttfts):
        return False
    tpots = collect_tpots([s])
    if tpots and _pct(tpots, 95) > thr.tpot_s:
        return False
    return True


def build_report(policy: str, sessions: Sequence[Session],
                 wall_time_s: float,
                 thresholds: Optional[SLOThresholds] = None,
                 extra: Optional[Dict[str, float]] = None) -> ServingReport:
    ttfts = collect_ttfts(sessions)
    tpots = collect_tpots(sessions)
    total_tokens = sum(s.output_tokens() for s in sessions)
    slo = float("nan")
    if thresholds is not None:
        oks = [session_slo_ok(s, thresholds) for s in sessions]
        slo = float(np.mean(oks)) if oks else float("nan")
    return ServingReport(
        policy=policy,
        num_sessions=len(sessions),
        wall_time_s=wall_time_s,
        ttft_p50_s=_pct(ttfts, 50),
        ttft_p95_s=_pct(ttfts, 95),
        tpot_p50_s=_pct(tpots, 50),
        tpot_p95_s=_pct(tpots, 95),
        throughput_tok_s=total_tokens / max(wall_time_s, 1e-9),
        slo_attainment=slo,
        total_output_tokens=total_tokens,
        extra=extra or {},
    )
