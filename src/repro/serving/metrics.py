"""Serving metrics: TTFT, TPOT, throughput, session-level SLO attainment.

Definitions follow the paper §IV-A exactly:
  TTFT  — request submission -> first output token (per request: the
          cold prefill and every resume prefill each start a request).
  TPOT  — inter-token latency within decode bursts.
  throughput — aggregate output tokens / wall time.
  SLO attainment — fraction of *sessions* whose every request met the
          TTFT bound AND whose TPOT stayed within the TPOT bound
          (joint criterion; we use per-session max TTFT and p95 TPOT).
Thresholds are calibrated per model-device pair by scaling isolated
(single-session, unloaded) performance by a constant factor, as §IV-A
prescribes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Session


def _pct(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else float("nan")


@dataclasses.dataclass
class SLOThresholds:
    ttft_s: float
    tpot_s: float

    @classmethod
    def from_isolated(cls, isolated_ttft_s: float, isolated_tpot_s: float,
                      factor: float = 3.0) -> "SLOThresholds":
        return cls(ttft_s=isolated_ttft_s * factor,
                   tpot_s=isolated_tpot_s * factor)


@dataclasses.dataclass
class ServingReport:
    policy: str
    num_sessions: int
    wall_time_s: float
    ttft_p50_s: float
    ttft_p95_s: float
    tpot_p50_s: float
    tpot_p95_s: float
    throughput_tok_s: float
    slo_attainment: float
    total_output_tokens: int
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)
    # telemetry-overhead self-check (DESIGN.md §11): tok/s cost of
    # tracing, measured by the hotpath bench as (off - on) / off over
    # best-of-N paired runs; NaN when the run didn't measure it
    telemetry_overhead_pct: float = float("nan")

    def row(self) -> str:
        return (f"{self.policy},{self.num_sessions},{self.wall_time_s:.3f},"
                f"{self.ttft_p50_s * 1e3:.1f},{self.ttft_p95_s * 1e3:.1f},"
                f"{self.tpot_p50_s * 1e3:.1f},{self.tpot_p95_s * 1e3:.1f},"
                f"{self.throughput_tok_s:.1f},{self.slo_attainment:.3f},"
                f"{self.telemetry_overhead_pct:.2f}")

    HEADER = ("policy,sessions,wall_s,ttft_p50_ms,ttft_p95_ms,"
              "tpot_p50_ms,tpot_p95_ms,throughput_tok_s,slo_rate,"
              "telemetry_overhead_pct")


def collect_ttfts(sessions: Sequence[Session]) -> List[float]:
    out = []
    for s in sessions:
        for arr, first in zip(s.request_arrivals, s.first_token_s):
            out.append(first - arr)
    return out


def collect_tpots(sessions: Sequence[Session]) -> List[float]:
    """Inter-token gaps within each contiguous decode burst."""
    out = []
    for s in sessions:
        ts = np.asarray(s.token_times_s)
        firsts = set(np.round(s.first_token_s, 9).tolist())
        gaps = np.diff(ts)
        for i, g in enumerate(gaps):
            # a gap that ends on a burst-first token spans a tool call /
            # prefill; exclude it from TPOT
            if round(float(ts[i + 1]), 9) not in firsts:
                out.append(float(g))
    return out


def session_slo_ok(s: Session, thr: SLOThresholds) -> bool:
    ttfts = [f - a for a, f in zip(s.request_arrivals, s.first_token_s)]
    if any(t > thr.ttft_s for t in ttfts):
        return False
    tpots = collect_tpots([s])
    if tpots and _pct(tpots, 95) > thr.tpot_s:
        return False
    return True


def collect_queue_delays(sessions: Sequence[Session]) -> List[float]:
    """Per-request admission wait (request ready -> admitted)."""
    out: List[float] = []
    for s in sessions:
        out.extend(s.queue_delays_s)
    return out


def collect_open_loop_ttfts(sessions: Sequence[Session]) -> List[float]:
    """Open-loop TTFT: request *ready* (arrival-process timestamp or
    tool completion) -> first token.  Differs from the closed-loop TTFT
    by the queue delay — under open-loop pressure the admission wait is
    the dominant term, and hiding it would make an overloaded server
    look healthy."""
    out = []
    for s in sessions:
        for arr, first, qd in zip(s.request_arrivals, s.first_token_s,
                                  s.queue_delays_s):
            out.append((first - arr) + qd)
    return out


@dataclasses.dataclass
class OpenLoopReport:
    """Goodput-vs-offered-rate row for the gateway benchmark.

    ``goodput_tok_s`` counts output tokens only from sessions that met
    the SLO (equal to throughput when no thresholds are given);
    ``rejected`` counts 429-style watermark shed."""
    policy: str
    offered_rps: float
    submitted: int
    completed: int
    rejected: int
    wall_time_s: float
    goodput_tok_s: float
    throughput_tok_s: float
    ttft_p50_s: float
    ttft_p95_s: float
    tpot_p50_s: float
    tpot_p95_s: float
    queue_delay_p50_s: float
    queue_delay_p95_s: float
    slo_attainment: float
    aborted: int = 0                 # fault/deadline/disconnect terminals
    # per-reason abort attribution (e.g. {"deadline": 3}) — a dict, so
    # excluded from the CSV row
    abort_reasons: Dict[str, int] = dataclasses.field(default_factory=dict)
    # telemetry-overhead self-check (see ServingReport)
    telemetry_overhead_pct: float = float("nan")

    def row(self) -> str:
        return (f"{self.policy},{self.offered_rps:.3f},{self.submitted},"
                f"{self.completed},{self.rejected},{self.aborted},"
                f"{self.wall_time_s:.3f},"
                f"{self.goodput_tok_s:.1f},{self.throughput_tok_s:.1f},"
                f"{self.ttft_p50_s * 1e3:.1f},{self.ttft_p95_s * 1e3:.1f},"
                f"{self.tpot_p50_s * 1e3:.1f},{self.tpot_p95_s * 1e3:.1f},"
                f"{self.queue_delay_p50_s * 1e3:.1f},"
                f"{self.queue_delay_p95_s * 1e3:.1f},"
                f"{self.slo_attainment:.3f},"
                f"{self.telemetry_overhead_pct:.2f}")

    HEADER = ("policy,offered_rps,submitted,completed,rejected,aborted,"
              "wall_s,goodput_tok_s,throughput_tok_s,ttft_p50_ms,"
              "ttft_p95_ms,tpot_p50_ms,tpot_p95_ms,qdelay_p50_ms,"
              "qdelay_p95_ms,slo_rate,telemetry_overhead_pct")


def collect_abort_reasons(sessions: Sequence[Session]) -> Dict[str, int]:
    """Per-reason histogram over aborted sessions (DESIGN.md §10) —
    the per-session ``abort_reason`` is set by ``abort_session``."""
    out: Dict[str, int] = {}
    for s in sessions:
        reason = getattr(s, "abort_reason", None)
        if reason:
            out[reason] = out.get(reason, 0) + 1
    return out


def build_open_loop_report(policy: str, sessions: Sequence[Session],
                           wall_time_s: float, offered_rps: float,
                           rejected: int = 0,
                           thresholds: Optional[SLOThresholds] = None,
                           aborted_sessions: Sequence[Session] = (),
                           ) -> OpenLoopReport:
    """Open-loop rollup over the *completed* sessions of one offered-rate
    run (rejected submissions are counted, not measured; aborted
    sessions contribute only their count and abort reason)."""
    ttfts = collect_open_loop_ttfts(sessions)
    tpots = collect_tpots(sessions)
    qdelays = collect_queue_delays(sessions)
    total_tokens = sum(s.output_tokens() for s in sessions)
    wall = max(wall_time_s, 1e-9)
    slo = float("nan")
    good_tokens = total_tokens
    if thresholds is not None and sessions:
        oks = [session_slo_ok(s, thresholds) for s in sessions]
        slo = float(np.mean(oks))
        good_tokens = sum(s.output_tokens()
                          for s, ok in zip(sessions, oks) if ok)
    return OpenLoopReport(
        policy=policy,
        offered_rps=offered_rps,
        submitted=len(sessions) + rejected + len(aborted_sessions),
        completed=len(sessions),
        rejected=rejected,
        aborted=len(aborted_sessions),
        abort_reasons=collect_abort_reasons(aborted_sessions),
        wall_time_s=wall_time_s,
        goodput_tok_s=good_tokens / wall,
        throughput_tok_s=total_tokens / wall,
        ttft_p50_s=_pct(ttfts, 50),
        ttft_p95_s=_pct(ttfts, 95),
        tpot_p50_s=_pct(tpots, 50),
        tpot_p95_s=_pct(tpots, 95),
        queue_delay_p50_s=_pct(qdelays, 50),
        queue_delay_p95_s=_pct(qdelays, 95),
        slo_attainment=slo,
    )


def build_report(policy: str, sessions: Sequence[Session],
                 wall_time_s: float,
                 thresholds: Optional[SLOThresholds] = None,
                 extra: Optional[Dict[str, float]] = None) -> ServingReport:
    ttfts = collect_ttfts(sessions)
    tpots = collect_tpots(sessions)
    total_tokens = sum(s.output_tokens() for s in sessions)
    slo = float("nan")
    if thresholds is not None:
        oks = [session_slo_ok(s, thresholds) for s in sessions]
        slo = float(np.mean(oks)) if oks else float("nan")
    return ServingReport(
        policy=policy,
        num_sessions=len(sessions),
        wall_time_s=wall_time_s,
        ttft_p50_s=_pct(ttfts, 50),
        ttft_p95_s=_pct(ttfts, 95),
        tpot_p50_s=_pct(tpots, 50),
        tpot_p95_s=_pct(tpots, 95),
        throughput_tok_s=total_tokens / max(wall_time_s, 1e-9),
        slo_attainment=slo,
        total_output_tokens=total_tokens,
        extra=extra or {},
    )
