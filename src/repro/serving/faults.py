"""Deterministic fault injection: the chaos layer (DESIGN.md §10).

The fault-domain claim this repo makes — any single-session fault
degrades exactly one session, and capacity faults degrade *throughput*,
never correctness — is only worth anything if it is testable.  This
module provides the test substrate: a seeded ``FaultPlan`` decides, up
front and reproducibly, which sessions experience which faults:

  * ``tool_error`` / ``tool_hang`` — the gateway's ``_tool_wait``
    consults the plan per (session, turn, attempt): an error raises
    ``InjectedFault`` inside the tool call, a hang sleeps past the
    configured tool timeout.  Faults can hit only the first k attempts
    (``attempts``), exercising retry recovery, or every attempt,
    exercising the on-exhaustion policy.
  * ``step_error`` — the engine's dispatch paths call ``check_step``
    before touching device state; the plan raises ``SessionFault`` for
    the armed session at its n-th dispatch, exercising engine-level
    quarantine (``abort_session``) instead of loop death.
  * ``page_exhaustion`` — installed as the pool's ``fault_hook``: the
    plan counts page allocations and fails a chosen consecutive range,
    exercising ``KVExhausted`` deferral + admission shedding.
  * ``disconnect`` — consumed by the *client* side (``drive_chaos``):
    the consumer cancels its ``LiveSession`` after receiving a chosen
    number of tokens, exercising prompt resource reclamation.

A ``FaultPlan`` instance carries per-run mutable counters (attempt
numbers, allocation index), so build a fresh plan per run; given the
same seed and the same call sequence the injected faults are identical.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kvcache import KVExhausted
from repro.serving.request import Session, SessionState


class InjectedFault(RuntimeError):
    """A chaos-injected tool failure (distinguishable in logs from real
    tool errors; handled identically)."""


class SessionFault(RuntimeError):
    """A fault attributable to exactly one session.  ``step()`` catches
    it and quarantines (aborts) that session; every other session's
    cycle proceeds."""

    def __init__(self, session_id: int, reason: str):
        super().__init__(f"session {session_id}: {reason}")
        self.session_id = session_id
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault."""
    kind: str                 # tool_error | tool_hang | step_error |
    #                           page_exhaustion | disconnect
    session_id: int = -1      # target (all kinds except page_exhaustion)
    turn_idx: int = -1        # tool faults: which tool call (-1 = every)
    attempts: int = 10 ** 9   # tool faults: fail the first k attempts
    at_count: int = 0         # page_exhaustion: first failing alloc index
    #                           step_error: dispatch index that faults
    count: int = 1            # page_exhaustion: consecutive failing allocs
    at_token: int = 1         # disconnect: cancel after this many tokens
    hang_s: float = 3600.0    # tool_hang: sleep length (>> any timeout)


class FaultPlan:
    """Seeded, deterministic fault schedule + per-run injection state."""

    def __init__(self, specs: Tuple[FaultSpec, ...] = (), seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed
        # per-run mutable injection state
        self._page_allocs = 0             # pool allocation call index
        self._dispatches: Dict[int, int] = {}   # sid -> dispatch count
        self._tool_specs: Dict[int, List[FaultSpec]] = {}
        self._step_specs: Dict[int, FaultSpec] = {}
        self._step_fired: set = set()
        self._disconnects: Dict[int, int] = {}
        self._page_ranges: List[Tuple[int, int]] = []
        for sp in self.specs:
            if sp.kind in ("tool_error", "tool_hang"):
                self._tool_specs.setdefault(sp.session_id, []).append(sp)
            elif sp.kind == "step_error":
                self._step_specs[sp.session_id] = sp
            elif sp.kind == "disconnect":
                self._disconnects[sp.session_id] = sp.at_token
            elif sp.kind == "page_exhaustion":
                self._page_ranges.append((sp.at_count,
                                          sp.at_count + sp.count))
            else:
                raise ValueError(f"unknown fault kind {sp.kind}")
        self.injected = {"tool_error": 0, "tool_hang": 0, "step_error": 0,
                         "page_exhaustion": 0}

    # ---- construction -------------------------------------------------
    @classmethod
    def generate(cls, seed: int, num_sessions: int, *,
                 tool_error_rate: float = 0.0,
                 tool_hang_rate: float = 0.0,
                 step_error_rate: float = 0.0,
                 disconnect_rate: float = 0.0,
                 page_fault_bursts: int = 0,
                 page_burst_len: int = 3,
                 recover_fraction: float = 0.5) -> "FaultPlan":
        """Draw a fault schedule: each session independently suffers at
        most one fault kind (rates are per-session probabilities, in the
        order tool_error > tool_hang > step_error > disconnect), plus
        ``page_fault_bursts`` bursts of failing page allocations spread
        over the run.  ``recover_fraction`` of tool errors hit only the
        first attempt (a retry then succeeds)."""
        rng = np.random.default_rng(seed)
        specs: List[FaultSpec] = []
        for sid in range(num_sessions):
            u = rng.random()
            if u < tool_error_rate:
                recover = rng.random() < recover_fraction
                specs.append(FaultSpec(
                    kind="tool_error", session_id=sid, turn_idx=-1,
                    attempts=1 if recover else 10 ** 9))
            elif u < tool_error_rate + tool_hang_rate:
                specs.append(FaultSpec(kind="tool_hang", session_id=sid))
            elif u < tool_error_rate + tool_hang_rate + step_error_rate:
                specs.append(FaultSpec(
                    kind="step_error", session_id=sid,
                    at_count=int(rng.integers(0, 4))))
            elif u < (tool_error_rate + tool_hang_rate + step_error_rate
                      + disconnect_rate):
                specs.append(FaultSpec(
                    kind="disconnect", session_id=sid,
                    at_token=int(rng.integers(1, 6))))
        for _ in range(page_fault_bursts):
            specs.append(FaultSpec(
                kind="page_exhaustion",
                at_count=int(rng.integers(4, 64)),
                count=page_burst_len))
        return cls(tuple(specs), seed=seed)

    # ---- engine-side hooks --------------------------------------------
    def pool_hook(self, what: str) -> None:
        """Installed as ``KVCachePool.fault_hook``: raise ``KVExhausted``
        for page allocations inside a planned failure burst."""
        if what != "page":
            return
        i = self._page_allocs
        self._page_allocs += 1
        for lo, hi in self._page_ranges:
            if lo <= i < hi:
                self.injected["page_exhaustion"] += 1
                raise KVExhausted(
                    "page", f"injected page exhaustion (alloc #{i})")

    def check_step(self, session_id: int) -> None:
        """Called by the engine before dispatching work for a session;
        raises ``SessionFault`` at the armed dispatch index."""
        sp = self._step_specs.get(session_id)
        if sp is None or session_id in self._step_fired:
            return
        n = self._dispatches.get(session_id, 0)
        self._dispatches[session_id] = n + 1
        if n >= sp.at_count:
            self._step_fired.add(session_id)
            self.injected["step_error"] += 1
            raise SessionFault(session_id, "injected_step_error")

    # ---- gateway-side hooks -------------------------------------------
    def tool_fault(self, session_id: int, turn_idx: int,
                   attempt: int) -> Optional[FaultSpec]:
        """The fault (if any) for this tool-call attempt."""
        for sp in self._tool_specs.get(session_id, ()):
            if sp.turn_idx not in (-1, turn_idx) or attempt >= sp.attempts:
                continue
            self.injected[sp.kind] += 1
            return sp
        return None

    # ---- client-side hooks --------------------------------------------
    def disconnect_at(self, session_id: int) -> Optional[int]:
        """Token count after which the client should cancel (None = no
        planned disconnect for this session)."""
        return self._disconnects.get(session_id)

    def faulted_sessions(self) -> set:
        """Session ids with a *terminal* planned fault (ones expected to
        abort rather than complete; recoverable tool errors excluded).
        Page-exhaustion bursts target no session — they are transparent
        deferrals unless the defer limit trips."""
        out = set()
        for sp in self.specs:
            if sp.kind == "step_error" or sp.kind == "disconnect":
                out.add(sp.session_id)
            elif sp.kind in ("tool_error", "tool_hang") \
                    and sp.attempts >= 10 ** 9:
                out.add(sp.session_id)
        return out


# ---------------------------------------------------------------------------
# chaos driver (benchmarks/chaos.py, tests/test_faults.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChaosRun:
    """What one faulted open-loop drive observed, client-side."""
    completed: List[Session]
    aborted: List[Session]
    rejected: List[Session]
    events: List[Tuple[float, object]]      # (driver wall time, event)
    recovery_s: List[float]                 # cancel -> terminal latency
    wall_s: float = 0.0

    def wedged(self) -> int:
        """Sessions that reached no terminal state — must be zero."""
        terminal = {s.session_id for s in self.completed} \
            | {s.session_id for s in self.aborted} \
            | {s.session_id for s in self.rejected}
        seen = {e.session_id for _, e in self.events}
        return len(seen - terminal)

    def streams(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _, ev in self.events:
            if not getattr(ev, "error", False):
                out.setdefault(ev.session_id, []).append(ev.token)
        return out


async def drive_chaos(gateway, sessions: List[Session], arrivals,
                      plan: FaultPlan, *, time_scale: float = 1.0,
                      ) -> ChaosRun:
    """Open-loop driver with client-side disconnect injection: submit at
    the arrival offsets, consume every stream, and cancel sessions the
    plan marks for mid-stream disconnect after their chosen token count.
    Every consumer runs to its stream terminator — a wedged (never
    terminated) stream would hang this driver, which is exactly the
    regression the chaos suite exists to catch (callers bound it with
    ``asyncio.wait_for``)."""
    from repro.serving.gateway import Rejected   # circular-safe at runtime
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    run = ChaosRun(completed=[], aborted=[], rejected=[], events=[],
                   recovery_s=[])

    async def one(sess: Session, at: float) -> None:
        delay = at * time_scale - (loop.time() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        res = await gateway.submit(sess)
        if isinstance(res, Rejected):
            run.rejected.append(sess)
            return
        cut = plan.disconnect_at(sess.session_id)
        tokens, cancel_t, errored = 0, None, False
        async for ev in res.events():
            run.events.append((loop.time() - t0, ev))
            errored |= bool(getattr(ev, "error", False))
            if not getattr(ev, "error", False):
                tokens += 1
            if cut is not None and tokens >= cut and cancel_t is None:
                res.cancel()
                cancel_t = loop.time()
        if cancel_t is not None:
            run.recovery_s.append(loop.time() - cancel_t)
        if errored or sess.state == SessionState.ABORTED:
            run.aborted.append(sess)
        else:
            run.completed.append(sess)

    await asyncio.gather(*(one(s, float(a))
                           for s, a in zip(sessions, arrivals)))
    run.wall_s = loop.time() - t0
    return run
