"""KV-cache pool: slot allocation, length tracking, prefix caching.

One engine owns one pool — the paper's single-engine shared-memory-pool
design (§III-C): prefill writes and decode reads the *same* buffers, so
a completed prefill's KV is visible to decode with no transfer; slot
lifetime is managed host-side (the CPU-mutex role), and ordering within
a step is guaranteed by JAX's functional update semantics (the
cudaEvent role).

Prefix cache (§II-A substrate): after a cold prefill of a shared system
prompt, the engine registers a *snapshot* of that slot's cache rows at
that length.  A later cold prefill with an identical token prefix copies
the snapshot instead of recomputing.  Snapshotting (rather than pointing
at the donor slot) is what makes this correct for SSM/hybrid layers
too: a recurrent state is a point summary valid only at the exact
length it was taken, and the donor immediately advances past it —
Marconi (paper ref [9], MLSys'25) makes the same observation for
hybrid-LLM prefix caching.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import POSITIONAL_CACHE_KEYS, init_cache


def _prefix_key(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(tokens, dtype=np.int32)
                        .tobytes()).hexdigest()


# One executable per cache pytree structure/shape (jit keys on both), so
# a prefix restore is a single fused scatter dispatch instead of one
# ``.at[].set`` dispatch per leaf — O(copy), not O(dispatch·leaves).
# ``slot`` is a traced scalar (no recompile per slot); the cache buffer
# is donated so XLA writes the restored rows in place.
@functools.partial(jax.jit, donate_argnums=(0,))
def _fused_restore(cache, snapshot, slot):
    return jax.tree.map(lambda leaf, snap: leaf.at[:, slot].set(snap),
                        cache, snapshot)


@jax.jit
def _fused_snapshot(cache, slot):
    return jax.tree.map(lambda leaf: leaf[:, slot], cache)


@dataclasses.dataclass
class PrefixEntry:
    snapshot: Any          # pytree: each cache leaf's [:, slot] rows
    length: int
    refs: int = 0
    last_used: int = 0     # LRU tick (register / lookup-hit time)


class KVCachePool:
    """Fixed number of batch slots over one stacked cache pytree."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_seq: int,
                 dtype=jnp.float32, max_prefix_entries: int = 8):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, num_slots, max_seq, dtype)
        self.lengths = np.zeros((num_slots,), np.int32)
        self._free = set(range(num_slots))
        self._prefix: Dict[str, PrefixEntry] = {}
        self.max_prefix_entries = max_prefix_entries
        self._tick = 0                      # LRU clock for prefix entries
        self._has_state_leaves = any(
            not set(layer) <= POSITIONAL_CACHE_KEYS
            for layer in self.cache.values())
        self.stats = {"alloc": 0, "free": 0, "prefix_hits": 0,
                      "prefix_misses": 0, "prefix_refreshes": 0,
                      "evictions": 0, "parks": 0, "unparks": 0}

    # ---- slot lifecycle -------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KV pool exhausted: no free slot")
        slot = min(self._free)
        self._free.discard(slot)
        self.lengths[slot] = 0
        if self._has_state_leaves:
            self.reset_slot_state(slot)
        self.stats["alloc"] += 1
        return slot

    def reset_slot_state(self, slot: int) -> None:
        """Zero the slot's *stateful* (SSM) leaves.  Attention KV rows
        are naturally fenced by ``lengths``, but a recurrent state is a
        full-tensor summary: a freed session's state must not seed the
        next occupant's prefill."""
        def zero(layer):
            if set(layer) <= POSITIONAL_CACHE_KEYS:
                return layer
            return {k: v.at[:, slot].set(0) for k, v in layer.items()}
        self.cache = {name: zero(layer)
                      for name, layer in self.cache.items()}

    def free(self, slot: int) -> None:
        self._free.add(slot)
        self.lengths[slot] = 0
        self.stats["free"] += 1

    @property
    def free_slots(self) -> int:
        return len(self._free)

    # ---- prefix cache ---------------------------------------------------
    def register_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Snapshot ``slot``'s cache rows as a reusable prefix.  Must be
        called when exactly ``len(tokens)`` tokens are in the slot.

        Re-registering an already-cached key only refreshes its LRU
        stamp: re-snapshotting would waste a device gather and, at
        capacity, needlessly evict a *different* entry to make room for
        a byte-identical one."""
        assert self.lengths[slot] == len(tokens), \
            (self.lengths[slot], len(tokens))
        key = _prefix_key(tokens)
        self._tick += 1
        entry = self._prefix.get(key)
        if entry is not None:
            entry.last_used = self._tick
            self.stats["prefix_refreshes"] += 1
            return
        if len(self._prefix) >= self.max_prefix_entries:
            self._evict_one()
        self._prefix[key] = PrefixEntry(
            snapshot=_fused_snapshot(self.cache, jnp.int32(slot)),
            length=len(tokens), last_used=self._tick)

    def lookup(self, tokens: np.ndarray) -> Optional[PrefixEntry]:
        entry = self._prefix.get(_prefix_key(tokens))
        if entry is not None:
            self.stats["prefix_hits"] += 1
            entry.refs += 1
            self._tick += 1
            entry.last_used = self._tick
        else:
            self.stats["prefix_misses"] += 1
        return entry

    def restore_prefix(self, dst_slot: int, entry: PrefixEntry) -> None:
        """Copy a snapshot into ``dst_slot`` (attn rows + SSM states) as
        one fused jitted scatter — a prefix hit costs O(copy), not
        O(dispatch·leaves) host round-trips."""
        self.cache = _fused_restore(self.cache, entry.snapshot,
                                    jnp.int32(dst_slot))
        self.lengths[dst_slot] = entry.length

    def _evict_one(self) -> None:
        """Evict the least-recently-used entry.  (Min-``refs`` eviction —
        the previous policy — permanently favours old hot prefixes and
        thrashes fresh ones: a new deployment's prompt always has the
        fewest hits and is evicted first, forever.)"""
        if not self._prefix:
            return
        key = min(self._prefix, key=lambda k: self._prefix[k].last_used)
        del self._prefix[key]
        self.stats["evictions"] += 1

    # ---- tool-wait parking ----------------------------------------------
    def park(self, slot: int) -> PrefixEntry:
        """Snapshot a slot's full cache rows (attention KV + SSM states)
        and free the slot — the release-under-pressure half of the
        TOOL_WAIT policy.  Unlike prefix entries, the caller owns the
        returned snapshot (it is not registered in the LRU-evictable
        prefix store), so a parked session can never lose its state to
        cache churn."""
        entry = PrefixEntry(
            snapshot=_fused_snapshot(self.cache, jnp.int32(slot)),
            length=int(self.lengths[slot]))
        self.free(slot)
        self.stats["parks"] += 1
        return entry

    def unpark(self, slot: int, entry: PrefixEntry) -> None:
        """Restore a parked snapshot into a freshly allocated slot.  The
        restore is the same fused scatter as a prefix hit, and exact at
        the parked length, so the subsequent resume prefill sees
        bit-identical state to a session that held its slot."""
        self.restore_prefix(slot, entry)
        self.stats["unparks"] += 1

    # ---- step integration -------------------------------------------------
    def lengths_device(self) -> jax.Array:
        return jnp.asarray(self.lengths)

    def commit(self, new_cache, slot_mask: np.ndarray) -> None:
        """Accept updated cache rows for slots in ``slot_mask`` (bool [B]),
        keeping old rows elsewhere (protects inactive sessions' SSM
        states from being advanced by masked lanes)."""
        if slot_mask.all():
            self.cache = new_cache
            return
        m = jnp.asarray(slot_mask)

        def sel(new, old):
            shape = [1, self.num_slots] + [1] * (new.ndim - 2)
            return jnp.where(m.reshape(shape), new, old)
        self.cache = jax.tree.map(sel, new_cache, self.cache)

    def bytes_per_slot(self) -> int:
        total = sum(l.size * l.dtype.itemsize
                    for l in jax.tree_util.tree_leaves(self.cache))
        return total // self.num_slots
