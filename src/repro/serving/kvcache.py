"""KV-cache pool: slot allocation, length tracking, prefix caching.

One engine owns one pool — the paper's single-engine shared-memory-pool
design (§III-C): prefill writes and decode reads the *same* buffers, so
a completed prefill's KV is visible to decode with no transfer; slot
lifetime is managed host-side (the CPU-mutex role), and ordering within
a step is guaranteed by JAX's functional update semantics (the
cudaEvent role).

Prefix cache (§II-A substrate): after a cold prefill of a shared system
prompt, the engine registers a *snapshot* of that slot's cache rows at
that length.  A later cold prefill with an identical token prefix copies
the snapshot instead of recomputing.  Snapshotting (rather than pointing
at the donor slot) is what makes this correct for SSM/hybrid layers
too: a recurrent state is a point summary valid only at the exact
length it was taken, and the donor immediately advances past it —
Marconi (paper ref [9], MLSys'25) makes the same observation for
hybrid-LLM prefix caching.

Paged layout (``PagedKVCachePool``, DESIGN.md §8): positional leaves
(attention K/V + quant scales) live in a flat page arena
``[num_pages + 1, page_size, ...]`` addressed through per-slot block
tables; SSM leaves stay per-slot point summaries (the Marconi argument
above — a recurrent state has no positional rows to share).  Pages are
*refcounted*: a prefix hit or a TOOL_WAIT park is block-table surgery
(O(metadata), zero device copies for the positional data), and the
first divergent write to a shared page triggers a one-page
copy-on-write.  The slab ``KVCachePool`` remains the reference /
parity oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import POSITIONAL_CACHE_KEYS, init_cache, num_kv_pages


class KVExhausted(RuntimeError):
    """Typed capacity fault: the pool has no free slot / page.

    A ``RuntimeError`` subclass so legacy catches keep working, but
    typed so the dispatcher can *degrade* — defer the op back to Q_P,
    shed at the admission watermark — instead of letting one
    over-committed cycle kill the serving loop (DESIGN.md §10)."""

    def __init__(self, what: str, msg: str):
        super().__init__(msg)
        self.what = what          # "slot" | "page"
        self.session_id = -1      # annotated at the dispatch site


def _prefix_key(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(tokens, dtype=np.int32)
                        .tobytes()).hexdigest()


# Public alias: callers that probe repeatedly (the planner view builds
# one probe per waiting session per cycle) hash once and peek by key.
prefix_key = _prefix_key


# One executable per cache pytree structure/shape (jit keys on both), so
# a prefix restore is a single fused scatter dispatch instead of one
# ``.at[].set`` dispatch per leaf — O(copy), not O(dispatch·leaves).
# ``slot`` is a traced scalar (no recompile per slot); the cache buffer
# is donated so XLA writes the restored rows in place.
@functools.partial(jax.jit, donate_argnums=(0,))
def _fused_restore(cache, snapshot, slot):
    return jax.tree.map(lambda leaf, snap: leaf.at[:, slot].set(snap),
                        cache, snapshot)


@jax.jit
def _fused_snapshot(cache, slot):
    return jax.tree.map(lambda leaf: leaf[:, slot], cache)


@dataclasses.dataclass
class PrefixEntry:
    snapshot: Any          # pytree: each cache leaf's [:, slot] rows
    length: int
    refs: int = 0
    last_used: int = 0     # LRU tick (register / lookup-hit time)


class KVCachePool:
    """Fixed number of batch slots over one stacked cache pytree."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_seq: int,
                 dtype=jnp.float32, max_prefix_entries: int = 8):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.cache = self._init_cache(cfg, num_slots, max_seq, dtype)
        self.lengths = np.zeros((num_slots,), np.int32)
        self._free = set(range(num_slots))
        self._prefix: Dict[str, PrefixEntry] = {}
        self.max_prefix_entries = max_prefix_entries
        self._tick = 0                      # LRU clock for prefix entries
        self._has_state_leaves = any(
            not set(layer) <= POSITIONAL_CACHE_KEYS
            for layer in self.cache.values())
        self.stats = {"alloc": 0, "free": 0, "prefix_hits": 0,
                      "prefix_misses": 0, "prefix_refreshes": 0,
                      "evictions": 0, "parks": 0, "unparks": 0}
        # chaos-injection point (serving/faults.py): called before every
        # slot / page allocation with the allocation kind; a FaultPlan
        # hook raises KVExhausted to simulate pressure deterministically
        self.fault_hook: Optional[Any] = None

    def _init_cache(self, cfg, num_slots, max_seq, dtype):
        return init_cache(cfg, num_slots, max_seq, dtype)

    # ---- slot lifecycle -------------------------------------------------
    def alloc(self) -> int:
        if self.fault_hook is not None:
            self.fault_hook("slot")
        if not self._free:
            raise KVExhausted("slot", "KV pool exhausted: no free slot")
        slot = min(self._free)
        self._free.discard(slot)
        self.lengths[slot] = 0
        if self._has_state_leaves:
            self.reset_slot_state(slot)
        self.stats["alloc"] += 1
        return slot

    def reset_slot_state(self, slot: int) -> None:
        """Zero the slot's *stateful* (SSM) leaves.  Attention KV rows
        are naturally fenced by ``lengths``, but a recurrent state is a
        full-tensor summary: a freed session's state must not seed the
        next occupant's prefill."""
        def zero(layer):
            if set(layer) <= POSITIONAL_CACHE_KEYS:
                return layer
            return {k: v.at[:, slot].set(0) for k, v in layer.items()}
        self.cache = {name: zero(layer)
                      for name, layer in self.cache.items()}

    def free(self, slot: int) -> None:
        self._check_allocated(slot)
        self._free.add(slot)
        self.lengths[slot] = 0
        self.stats["free"] += 1

    def _check_allocated(self, slot: int) -> None:
        """Freeing a slot that is not currently allocated must be loud:
        silently re-adding it to ``_free`` would hand the same slot to
        two sessions (and, under the paged layout, corrupt page
        refcounts)."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"free of invalid slot {slot}")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def slots_in_use(self) -> int:
        """Bound KV slots — the telemetry occupancy gauge."""
        return self.num_slots - len(self._free)

    # ---- prefix cache ---------------------------------------------------
    def register_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Snapshot ``slot``'s cache rows as a reusable prefix.  Must be
        called when exactly ``len(tokens)`` tokens are in the slot.

        Re-registering an already-cached key only refreshes its LRU
        stamp: re-snapshotting would waste a device gather and, at
        capacity, needlessly evict a *different* entry to make room for
        a byte-identical one."""
        assert self.lengths[slot] == len(tokens), \
            (self.lengths[slot], len(tokens))
        key = _prefix_key(tokens)
        self._tick += 1
        entry = self._prefix.get(key)
        if entry is not None:
            entry.last_used = self._tick
            self.stats["prefix_refreshes"] += 1
            return
        if len(self._prefix) >= self.max_prefix_entries:
            self._evict_one()
        self._prefix[key] = PrefixEntry(
            snapshot=_fused_snapshot(self.cache, jnp.int32(slot)),
            length=len(tokens), last_used=self._tick)

    def peek_prefix(self, tokens: np.ndarray) -> int:
        """Non-mutating probe: length of the cached prefix for these
        tokens (0 = miss).  No hit/miss stats, no LRU refresh — the
        planner's ``EngineView`` must not perturb cache recency; the
        actual ``lookup``/``restore_prefix`` happens at dispatch."""
        return self.peek_prefix_key(_prefix_key(tokens))

    def peek_prefix_key(self, key: str) -> int:
        """``peek_prefix`` for a pre-computed ``prefix_key`` — the
        engine caches the key per session so a waiting session costs no
        re-hash per cycle."""
        entry = self._prefix.get(key)
        return entry.length if entry is not None else 0

    def lookup(self, tokens: np.ndarray) -> Optional[PrefixEntry]:
        entry = self._prefix.get(_prefix_key(tokens))
        if entry is not None:
            self.stats["prefix_hits"] += 1
            entry.refs += 1
            self._tick += 1
            entry.last_used = self._tick
        else:
            self.stats["prefix_misses"] += 1
        return entry

    def restore_prefix(self, dst_slot: int, entry: PrefixEntry) -> None:
        """Copy a snapshot into ``dst_slot`` (attn rows + SSM states) as
        one fused jitted scatter — a prefix hit costs O(copy), not
        O(dispatch·leaves) host round-trips."""
        self.cache = _fused_restore(self.cache, entry.snapshot,
                                    jnp.int32(dst_slot))
        self.lengths[dst_slot] = entry.length

    def _evict_one(self) -> None:
        """Evict the least-recently-used entry.  (Min-``refs`` eviction —
        the previous policy — permanently favours old hot prefixes and
        thrashes fresh ones: a new deployment's prompt always has the
        fewest hits and is evicted first, forever.)"""
        if not self._prefix:
            return
        key = min(self._prefix, key=lambda k: self._prefix[k].last_used)
        self._drop_entry(self._prefix.pop(key))
        self.stats["evictions"] += 1

    def _drop_entry(self, entry) -> None:
        """Entry-eviction hook (the paged pool releases page refs)."""

    def release_entry(self, entry) -> None:
        """Release a caller-owned (parked) entry without restoring it —
        the abort path for a session parked in TOOL_WAIT.  Slab entries
        are plain snapshots (GC handles them); the paged pool drops the
        transferred page references."""
        self._drop_entry(entry)

    # ---- tool-wait parking ----------------------------------------------
    def park(self, slot: int) -> PrefixEntry:
        """Snapshot a slot's full cache rows (attention KV + SSM states)
        and free the slot — the release-under-pressure half of the
        TOOL_WAIT policy.  Unlike prefix entries, the caller owns the
        returned snapshot (it is not registered in the LRU-evictable
        prefix store), so a parked session can never lose its state to
        cache churn."""
        entry = PrefixEntry(
            snapshot=_fused_snapshot(self.cache, jnp.int32(slot)),
            length=int(self.lengths[slot]))
        self.free(slot)
        self.stats["parks"] += 1
        return entry

    def unpark(self, slot: int, entry: PrefixEntry) -> None:
        """Restore a parked snapshot into a freshly allocated slot.  The
        restore is the same fused scatter as a prefix hit, and exact at
        the parked length, so the subsequent resume prefill sees
        bit-identical state to a session that held its slot."""
        self.restore_prefix(slot, entry)
        self.stats["unparks"] += 1

    # ---- step integration -------------------------------------------------
    def lengths_device(self) -> jax.Array:
        return jnp.asarray(self.lengths)

    def commit(self, new_cache, slot_mask: np.ndarray) -> None:
        """Accept updated cache rows for slots in ``slot_mask`` (bool [B]),
        keeping old rows elsewhere (protects inactive sessions' SSM
        states from being advanced by masked lanes)."""
        if slot_mask.all():
            self.cache = new_cache
            return
        m = jnp.asarray(slot_mask)

        def sel(new, old):
            shape = [1, self.num_slots] + [1] * (new.ndim - 2)
            return jnp.where(m.reshape(shape), new, old)
        self.cache = jax.tree.map(sel, new_cache, self.cache)

    def bytes_per_slot(self) -> int:
        total = sum(l.size * l.dtype.itemsize
                    for l in jax.tree_util.tree_leaves(self.cache))
        return total // self.num_slots


# ---------------------------------------------------------------------------
# paged layout (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _is_positional(layer: Dict[str, Any]) -> bool:
    return set(layer) <= POSITIONAL_CACHE_KEYS


@functools.partial(jax.jit, donate_argnums=(0,))
def _fused_page_copy(cache, src, dst):
    """Copy one physical page (all positional leaves) — the COW cost of
    the first divergent write to a shared page.  O(page), not O(seq)."""
    def cp(layer):
        if _is_positional(layer):
            return {k: v.at[:, dst].set(v[:, src]) for k, v in layer.items()}
        return layer
    return {name: cp(layer) for name, layer in cache.items()}


@jax.jit
def _fused_state_snapshot(cache, slot):
    """Gather a slot's *stateful* (SSM) leaves only — the length-point
    summary a paged prefix/park entry must still carry on hybrid
    stacks (positional data is shared by page reference instead)."""
    return {name: {k: v[:, slot] for k, v in layer.items()}
            for name, layer in cache.items() if not _is_positional(layer)}


@functools.partial(jax.jit, donate_argnums=(0,))
def _fused_state_restore(cache, snap, slot):
    out = {}
    for name, layer in cache.items():
        if name in snap:
            out[name] = {k: v.at[:, slot].set(snap[name][k])
                         for k, v in layer.items()}
        else:
            out[name] = layer
    return out


@dataclasses.dataclass
class PagedEntry:
    """A paged prefix/park entry: shared page ids + (hybrid only) the
    SSM point snapshot.  Holding the entry holds one reference on every
    listed page."""
    pages: np.ndarray      # int32 [n] physical page ids (no -1 entries)
    length: int
    state: Any = None      # stateful-leaf snapshot, or None (dense)
    refs: int = 0
    last_used: int = 0


class PagedKVCachePool(KVCachePool):
    """Block-table pool over a flat page arena (DESIGN.md §8).

    Positional leaves: ``[G, num_pages + 1, page_size, Hk, hd]`` — the
    last physical page is the write scratch page (never read, never
    allocated).  Per-slot block tables map logical page index ->
    physical page; ``-1`` marks unallocated entries (substituted with
    the scratch page id in the device mirror, so padded/inactive writes
    land there).  Pages are refcounted:

    * ``register_prefix`` / ``restore_prefix`` (prefix hit) and
      ``park`` / ``unpark`` are block-table surgery — zero device
      copies for positional data (``stats["page_copies"]`` counts the
      exceptions; hybrid stacks pay one small SSM point-snapshot,
      ``stats["state_copies"]``).
    * Writers must call ``prepare_append(slot, start, n)`` before
      dispatching device work that writes positions ``[start,
      start+n)``: it allocates missing pages and copy-on-writes shared
      ones, so the model-side scatter never touches a page another
      session can read.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_seq: int,
                 dtype=jnp.float32, max_prefix_entries: int = 8,
                 num_pages: int = 0):
        assert cfg.kv_layout == "paged", cfg.kv_layout
        self.page_size = cfg.kv_page_size
        assert max_seq % self.page_size == 0, (max_seq, self.page_size)
        self.pages_per_slot = max_seq // self.page_size      # P_max
        self.num_pages = num_pages or num_kv_pages(cfg, num_slots, max_seq)
        self.scratch_page = self.num_pages    # last physical arena page
        super().__init__(cfg, num_slots, max_seq, dtype, max_prefix_entries)
        self.block_table = np.full((num_slots, self.pages_per_slot), -1,
                                   np.int32)
        self.refcount = np.zeros((self.num_pages,), np.int32)
        # LIFO free list popping low page ids first (determinism in tests)
        self._free_pages: List[int] = list(range(self.num_pages - 1, -1, -1))
        self._bt_dev: Optional[jax.Array] = None
        self.stats.update({"page_allocs": 0, "page_frees": 0,
                           "page_copies": 0, "state_copies": 0,
                           "shared_pages": 0})

    def _init_cache(self, cfg, num_slots, max_seq, dtype):
        return init_cache(cfg, num_slots, max_seq, dtype,
                          num_pages=self.num_pages)

    # ---- page accounting ------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def pages_in_use(self) -> int:
        """Allocated arena pages — the telemetry page-occupancy gauge."""
        return self.num_pages - len(self._free_pages)

    def _alloc_page(self) -> int:
        if self.fault_hook is not None:
            self.fault_hook("page")
        if not self._free_pages:
            raise KVExhausted("page", "KV page pool exhausted: no free page")
        p = self._free_pages.pop()
        self.refcount[p] = 1
        self.stats["page_allocs"] += 1
        return p

    def _incref(self, page: int) -> None:
        self.refcount[page] += 1

    def _decref(self, page: int) -> None:
        assert self.refcount[page] > 0, page
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free_pages.append(page)
            self.stats["page_frees"] += 1

    def _npages(self, length: int) -> int:
        return -(-length // self.page_size)

    # ---- slot lifecycle -------------------------------------------------
    def free(self, slot: int) -> None:
        self._check_allocated(slot)
        for p in self.block_table[slot]:
            if p >= 0:
                self._decref(int(p))
        self.block_table[slot] = -1
        self._bt_dev = None
        super().free(slot)

    def _release_slot(self, slot: int) -> None:
        """Return a slot whose page references were transferred to a
        parked entry — the table row is cleared WITHOUT decref."""
        self._check_allocated(slot)
        self.block_table[slot] = -1
        self._bt_dev = None
        self._free.add(slot)
        self.lengths[slot] = 0
        self.stats["free"] += 1

    def prepare_append(self, slot: int, start: int, n: int) -> None:
        """Make positions ``[start, start + n)`` of ``slot`` writable:
        allocate unmapped pages and copy-on-write shared ones.  Must run
        before any device dispatch that writes those positions (prefill
        chunk, decode step, megastep of K).  Positions beyond the
        table's extent are ignored — the model-side scatter redirects
        them to the scratch page (the engine counts such overruns)."""
        if n <= 0:
            return
        first = start // self.page_size
        last = self._npages(start + n)                # exclusive bound
        # an exhausted _alloc_page mid-call must not leak the pages this
        # same call already claimed: record each mutation and unwind in
        # reverse before re-raising, so a failed append leaves the table
        # row, refcounts and free-page count exactly as found
        undo: List[tuple] = []            # (lp, old_page, fresh_page)
        try:
            for lp in range(first, min(last, self.pages_per_slot)):
                page = int(self.block_table[slot, lp])
                if page < 0:
                    fresh = self._alloc_page()
                    self.block_table[slot, lp] = fresh
                    self._bt_dev = None
                    undo.append((lp, -1, fresh))
                elif self.refcount[page] > 1:
                    fresh = self._alloc_page()
                    self.cache = _fused_page_copy(
                        self.cache, jnp.int32(page), jnp.int32(fresh))
                    self._decref(page)
                    self.block_table[slot, lp] = fresh
                    self._bt_dev = None
                    self.stats["page_copies"] += 1
                    undo.append((lp, page, fresh))
        except KVExhausted:
            for lp, old, fresh in reversed(undo):
                if old >= 0:
                    # the COW source kept refcount >= 1 (another holder),
                    # so re-increfing cannot resurrect a freed page
                    self._incref(old)
                self._decref(fresh)       # refcount 1 -> 0: back to free
                self.block_table[slot, lp] = old
            self._bt_dev = None
            raise

    def block_tables_device(self) -> jax.Array:
        """Device mirror of the block tables with ``-1`` entries mapped
        to the scratch page (so padded/inactive writes are harmlessly
        absorbed).  Rebuilt only after table mutations."""
        if self._bt_dev is None:
            host = np.where(self.block_table < 0, self.scratch_page,
                            self.block_table).astype(np.int32)
            self._bt_dev = jnp.asarray(host)
        return self._bt_dev

    # ---- prefix cache: zero-copy page sharing ---------------------------
    def register_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Share ``slot``'s prefix pages by reference: O(metadata), no
        device gather of positional data.  Hybrid stacks snapshot the
        (small) SSM point state — the only device work."""
        assert self.lengths[slot] == len(tokens), \
            (self.lengths[slot], len(tokens))
        key = _prefix_key(tokens)
        self._tick += 1
        entry = self._prefix.get(key)
        if entry is not None:
            entry.last_used = self._tick
            self.stats["prefix_refreshes"] += 1
            return
        if len(self._prefix) >= self.max_prefix_entries:
            self._evict_one()
        pages = self.block_table[slot, :self._npages(len(tokens))].copy()
        assert (pages >= 0).all(), pages
        for p in pages:
            self._incref(int(p))
        self.stats["shared_pages"] += len(pages)
        state = None
        if self._has_state_leaves:
            state = _fused_state_snapshot(self.cache, jnp.int32(slot))
            self.stats["state_copies"] += 1
        self._prefix[key] = PagedEntry(pages=pages, length=len(tokens),
                                       state=state, last_used=self._tick)

    def restore_prefix(self, dst_slot: int, entry: PagedEntry) -> None:
        """A prefix hit: point ``dst_slot``'s table at the shared pages
        (refcount++) — zero positional device copies.  The first write
        past/into the shared tail page copy-on-writes via
        ``prepare_append``."""
        for i, p in enumerate(entry.pages):
            self._incref(int(p))
            self.block_table[dst_slot, i] = int(p)
        self._bt_dev = None
        self.lengths[dst_slot] = entry.length
        if entry.state is not None:
            self.cache = _fused_state_restore(self.cache, entry.state,
                                              jnp.int32(dst_slot))
            self.stats["state_copies"] += 1

    def _drop_entry(self, entry: PagedEntry) -> None:
        for p in entry.pages:
            self._decref(int(p))

    # ---- tool-wait parking: reference transfer --------------------------
    def park(self, slot: int) -> PagedEntry:
        """Park = transfer the slot's page references to the returned
        entry and free the slot — no device copy of positional data
        (hybrid: one SSM point snapshot).  The caller owns the entry;
        it is not registered in the LRU-evictable prefix store."""
        pages = self.block_table[slot]
        pages = pages[pages >= 0].copy()
        state = None
        if self._has_state_leaves:
            state = _fused_state_snapshot(self.cache, jnp.int32(slot))
            self.stats["state_copies"] += 1
        entry = PagedEntry(pages=pages, length=int(self.lengths[slot]),
                           state=state)
        self._release_slot(slot)          # refs move with the entry
        self.stats["parks"] += 1
        return entry

    def unpark(self, slot: int, entry: PagedEntry) -> None:
        """Restore a parked entry into a freshly allocated slot: the
        page references transfer back (no incref, no copy)."""
        self.block_table[slot, :len(entry.pages)] = entry.pages
        self._bt_dev = None
        self.lengths[slot] = entry.length
        if entry.state is not None:
            self.cache = _fused_state_restore(self.cache, entry.state,
                                              jnp.int32(slot))
            self.stats["state_copies"] += 1
        self.stats["unparks"] += 1

    # ---- step integration ----------------------------------------------
    def commit(self, new_cache, slot_mask: np.ndarray) -> None:
        """Paged commit: positional leaves are the shared arena (writes
        already landed page-exactly), so only stateful leaves need the
        inactive-lane protection."""
        m = jnp.asarray(slot_mask)

        def sel(name, new_l):
            if _is_positional(new_l):
                return new_l
            out = {}
            for k, n in new_l.items():
                shape = (1, self.num_slots) + (1,) * (n.ndim - 2)
                out[k] = jnp.where(m.reshape(shape), n, self.cache[name][k])
            return out
        self.cache = {name: sel(name, layer)
                      for name, layer in new_cache.items()}

    def arena_bytes(self) -> int:
        """Positional-arena footprint (the capacity denominator for the
        max-concurrent-sessions benchmark)."""
        return sum(
            l.size * l.dtype.itemsize
            for name, layer in self.cache.items() if _is_positional(layer)
            for l in layer.values())


def make_pool(cfg: ModelConfig, num_slots: int, max_seq: int,
              dtype=jnp.float32, max_prefix_entries: int = 8,
              num_pages: int = 0) -> KVCachePool:
    """Layout-dispatching pool factory (``ModelConfig.kv_layout``)."""
    if cfg.kv_layout == "paged":
        return PagedKVCachePool(cfg, num_slots, max_seq, dtype,
                                max_prefix_entries, num_pages)
    return KVCachePool(cfg, num_slots, max_seq, dtype, max_prefix_entries)
