"""Scheduling policies: AgentServe + the paper's three baselines + the
two ablations (§IV-A Baselines, §IV-D Ablation).

Every policy runs on the *same* engine machinery (same executables, same
KV pool, same workload) so measured differences come from scheduling
decisions only — the fairest single-substrate comparison we can make.

  agentserve — phase split, resume prefills fused into the decode stream
               under B_prefill(t), cold prefills chunked into the
               prefill stream sized by the slot partition, TPOT feedback
               (Algorithm 1), pre-established slots.
  pd_static  — SGLang-style PD disaggregation: decode protected, but a
               *static* partition, and all prefills (cold and resume)
               share one prefill queue.  (== the paper's No-Alg ablation
               when derived from agentserve.)
  chunked    — vLLM-style chunked prefill + continuous batching: fixed
               chunk budget mixed with decodes every cycle, single FCFS
               prefill queue, no phase awareness, no feedback.
  fcfs       — llama.cpp-style: strict arrival order; a prefill runs to
               completion before any decode step proceeds (the
               head-of-line blocking baseline).
  no_green   — agentserve minus pre-established slots: every partition
               change constructs its executable on demand *inside* the
               serving path.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    name: str
    adaptive: bool = False            # run Algorithm 1 feedback
    split_phases: bool = False        # distinguish cold vs resume
    resume_to_decode_queue: bool = False  # fuse in-budget resumes into Q_D
    protect_decode: bool = True       # decode step every cycle
    chunk_by_slots: bool = False      # prefill chunk = slot partition share
    fixed_chunk_frac: float = 0.5     # when not slot-driven: share of budget
    whole_prefill: bool = False       # fcfs: run prefill to completion
    preestablish: bool = True         # pre-build slot executables
    static_r_frac: float = 0.5        # static decode reservation share


AGENTSERVE = PolicySpec(
    name="agentserve", adaptive=True, split_phases=True,
    resume_to_decode_queue=True, protect_decode=True, chunk_by_slots=True)

PD_STATIC = PolicySpec(
    name="pd_static", adaptive=False, split_phases=True,
    resume_to_decode_queue=False, protect_decode=True, chunk_by_slots=True,
    static_r_frac=0.5)

CHUNKED = PolicySpec(
    name="chunked", adaptive=False, split_phases=False,
    resume_to_decode_queue=False, protect_decode=True, chunk_by_slots=False,
    fixed_chunk_frac=0.5)

FCFS = PolicySpec(
    name="fcfs", adaptive=False, split_phases=False,
    resume_to_decode_queue=False, protect_decode=False, whole_prefill=True)

NO_ALG = dataclasses.replace(AGENTSERVE, name="no_alg", adaptive=False)

NO_GREEN = dataclasses.replace(AGENTSERVE, name="no_green",
                               preestablish=False)

POLICIES = {p.name: p for p in
            [AGENTSERVE, PD_STATIC, CHUNKED, FCFS, NO_ALG, NO_GREEN]}
