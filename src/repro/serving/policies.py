"""Scheduling policies: AgentServe + the paper's three baselines + the
two ablations (§IV-A Baselines, §IV-D Ablation) + the SLO-class
extension.

Every policy runs on the *same* engine machinery (same executables, same
KV pool, same workload) so measured differences come from scheduling
decisions only — the fairest single-substrate comparison we can make.
Since the plan-based refactor (DESIGN.md §9) each policy's decisions
live in one pure ``CyclePlanner`` class (``core/planner.py``), consumed
identically by the real engine and the fluid simulator; the
``PolicySpec`` here carries its tunables plus the construction-time
knobs (which executable shapes to warm, pre-establish or not).

  agentserve — phase split, resume prefills fused into the decode stream
               under B_prefill(t), cold prefills chunked into the
               prefill stream sized by the slot partition, TPOT feedback
               (Algorithm 1), pre-established slots.
  pd_static  — SGLang-style PD disaggregation: decode protected, but a
               *static* partition, and all prefills (cold and resume)
               share one prefill queue.  (== the paper's No-Alg ablation
               when derived from agentserve.)
  chunked    — vLLM-style chunked prefill + continuous batching: fixed
               chunk budget mixed with decodes every cycle, single FCFS
               prefill queue, no phase awareness, no feedback.
  fcfs       — llama.cpp-style: strict arrival order; a prefill runs to
               completion before any decode step proceeds (the
               head-of-line blocking baseline).
  no_alg     — agentserve minus Algorithm 1 (static partition).
  no_green   — agentserve minus pre-established slots: every partition
               change constructs its executable on demand *inside* the
               serving path.
  priority   — agentserve plus SLO classes (interactive vs batch):
               interactive arrivals preempt batch cold prefills at chunk
               boundaries (KV stays resident via park/unpark).  The new
               capability the planner layer exists to make cheap; not in
               ``POLICIES`` (the paper's comparison set) but in
               ``PLANNERS`` (everything servable).
"""
from __future__ import annotations

import dataclasses

from repro.core.planner import (CyclePlanner, PolicySpec,
                                make_planner as _planner_from_spec)

__all__ = ["PolicySpec", "POLICIES", "PLANNERS", "make_planner",
           "AGENTSERVE", "PD_STATIC", "CHUNKED", "FCFS", "NO_ALG",
           "NO_GREEN", "PRIORITY"]


AGENTSERVE = PolicySpec(
    name="agentserve", adaptive=True, split_phases=True,
    resume_to_decode_queue=True, protect_decode=True, chunk_by_slots=True)

PD_STATIC = PolicySpec(
    name="pd_static", adaptive=False, split_phases=True,
    resume_to_decode_queue=False, protect_decode=True, chunk_by_slots=True,
    static_r_frac=0.5)

CHUNKED = PolicySpec(
    name="chunked", adaptive=False, split_phases=False,
    resume_to_decode_queue=False, protect_decode=True, chunk_by_slots=False,
    fixed_chunk_frac=0.5)

FCFS = PolicySpec(
    name="fcfs", adaptive=False, split_phases=False,
    resume_to_decode_queue=False, protect_decode=False, whole_prefill=True)

NO_ALG = dataclasses.replace(AGENTSERVE, name="no_alg", adaptive=False)

NO_GREEN = dataclasses.replace(AGENTSERVE, name="no_green",
                               preestablish=False)

PRIORITY = dataclasses.replace(AGENTSERVE, name="priority")

# The paper's comparison set (Fig 5/6/7).
POLICIES = {p.name: p for p in
            [AGENTSERVE, PD_STATIC, CHUNKED, FCFS, NO_ALG, NO_GREEN]}

# Everything the serving stack can run (launchers, gateway, sweeps).
PLANNERS = {**POLICIES, PRIORITY.name: PRIORITY}


def make_planner(policy) -> CyclePlanner:
    """Resolve a policy name, a ``PolicySpec``, or a ready planner
    instance (e.g. ``ReplayPlanner``) to a ``CyclePlanner``."""
    if isinstance(policy, str):
        policy = PLANNERS[policy]
    if isinstance(policy, PolicySpec):
        return _planner_from_spec(policy)
    if hasattr(policy, "plan") and hasattr(policy, "plan_control"):
        return policy
    raise TypeError(f"not a policy name, PolicySpec or planner: {policy!r}")
