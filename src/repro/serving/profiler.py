"""Phase-throughput profiling vs resource share (paper Fig 3).

Measures μ_D(R), μ_C(R), μ_R(R) on the engine's substrate: the resource
axis R is a share of the cycle token budget C (DESIGN.md §2).  A cycle
co-schedules one batched decode step with one prefill chunk; giving
decode share R means the chunk is C - R tokens, so

    μ_D(R) = B_decode   / (t_d + t_p(C - R))      [decode tokens/s]
    μ_C(R) = R          / (t_d + t_p_cold(R))     [cold-prefill tokens/s]
    μ_R(R) = R          / (t_d + t_p_resume(R))   [resume tokens/s]

with t_d the decode-step time and t_p(chunk) the chunk time measured at
a short (cold) or long (resume) cached context.  All three are monotone
in their own allocation (Assumption 1) and decode saturates at B/t_d as
R -> C — the Fig 3 shape.  The resulting ``ThroughputProfile`` feeds the
competitive-ratio analysis (Eq. 1-6) and benchmarks/fig3.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.competitive import ThroughputProfile
from repro.serving.engine import EngineConfig, get_executables
from repro.serving.kvcache import KVCachePool


def _timed(fn, reps: int) -> float:
    out = fn()                      # warm / compile
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out[0])
    return (time.perf_counter() - t0) / reps


def profile_throughput(mcfg: ModelConfig, params, *,
                       ecfg: Optional[EngineConfig] = None,
                       reps: int = 5, dtype=jnp.float32) -> ThroughputProfile:
    ecfg = ecfg or EngineConfig()
    C, g = ecfg.cycle_budget, ecfg.granularity
    levels = np.arange(g, C + 1, g)
    ex = get_executables(mcfg, ecfg.num_slots, ecfg.max_seq, ecfg.moe_mode)
    decode_fn, prefill_fn = ex.decode, ex.prefill
    pool = KVCachePool(mcfg, ecfg.num_slots, ecfg.max_seq, dtype)
    B = ecfg.num_slots
    ctx_long = ecfg.max_seq // 2
    pool.lengths[:] = ctx_long
    toks_b = jnp.zeros((B,), jnp.int32)
    lengths = jnp.asarray(pool.lengths)

    t_d = _timed(lambda: decode_fn(params, pool.cache, toks_b, lengths), reps)

    chunks = sorted({int(C - L) for L in levels if C - L > 0}
                    | {int(L) for L in levels})
    t_cold, t_res = {0: 0.0}, {0: 0.0}
    for ch in chunks:
        ptoks = jnp.zeros((1, ch), jnp.int32)
        t_cold[ch] = _timed(lambda: prefill_fn(
            params, pool.cache, ptoks, jnp.int32(0), jnp.int32(0),
            jnp.int32(ch - 1)), reps)
        t_res[ch] = _timed(lambda: prefill_fn(
            params, pool.cache, ptoks, jnp.int32(1), jnp.int32(ctx_long),
            jnp.int32(ch - 1)), reps)

    mu_d = [B / (t_d + t_cold[int(C - L)]) for L in levels]
    mu_c = [L / (t_d + t_cold[int(L)]) for L in levels]
    mu_r = [L / (t_d + t_res[int(L)]) for L in levels]
    return ThroughputProfile(
        levels=levels.astype(float),
        mu_decode=np.asarray(mu_d), mu_cold=np.asarray(mu_c),
        mu_resume=np.asarray(mu_r))
