"""Steppable engine reactor: the non-blocking serving surface.

The reactor is the seam between the cycle-synchronous engine (one
``ServingEngine.step()`` == one cycle: a decode megastep plus the
budgeted prefill work, DESIGN.md §2) and any *online* driver — the
asyncio gateway, a benchmark harness, or a test.  It owns request
handles, routes the engine's per-token events to them, and never
blocks: ``submit`` registers a session, ``step`` advances exactly one
cycle and returns the tokens it emitted, ``poll`` reads a handle's
progress.  The closed-loop ``ServingEngine.run()`` is reimplemented on
top of the same ``step()``, so the Fig-5 batch path and the online
gateway dispatch identical cycle code.

``TokenEvent`` is the engine's emission record: one decoded token for
one session, stamped with the engine clock.  ``turn_end`` marks the
last token of a decode burst (the agent is about to leave for a tool
call — the gateway's TOOL_WAIT trigger) and ``session_end`` the last
token of the final turn.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Deque, Dict, List, Optional

from repro.serving.request import Session, SessionState


@dataclasses.dataclass
class TokenEvent:
    """One emitted token. ``t`` is engine-clock seconds; ``index`` is the
    token's position within its turn's decode burst (0 == first token,
    emitted by the prefill completion)."""
    session_id: int
    token: int
    t: float
    turn_idx: int
    index: int
    first: bool = False          # first token of a burst (TTFT event)
    turn_end: bool = False       # burst complete -> tool call next
    session_end: bool = False    # final token of the final turn
    # fault-domain terminal (DESIGN.md §10): an aborted session's last
    # event carries error=True (token == -1) so stream consumers
    # distinguish failure from completion; abort_reason attributes it
    error: bool = False
    abort_reason: str = ""


class HandleStatus(enum.Enum):
    QUEUED = "queued"            # submitted, waiting for a KV slot
    PREFILL = "prefill"          # chunks in flight
    DECODE = "decode"
    TOOL_WAIT = "tool_wait"      # burst done; waiting on the tool clock
    DONE = "done"
    FAILED = "failed"            # aborted: fault / deadline / disconnect


_STATE_TO_STATUS = {
    SessionState.WAITING_PREFILL: HandleStatus.QUEUED,
    SessionState.PREFILLING: HandleStatus.PREFILL,
    SessionState.PREFILL_PAUSED: HandleStatus.PREFILL,
    SessionState.DECODING: HandleStatus.DECODE,
    SessionState.TOOL_CALL: HandleStatus.TOOL_WAIT,
    SessionState.TOOL_WAIT: HandleStatus.TOOL_WAIT,
    SessionState.FINISHED: HandleStatus.DONE,
    SessionState.ABORTED: HandleStatus.FAILED,
}


@dataclasses.dataclass
class RequestHandle:
    """Per-submission view: undelivered events plus live status."""
    session: Session
    events: Deque[TokenEvent] = dataclasses.field(
        default_factory=collections.deque)

    @property
    def session_id(self) -> int:
        return self.session.session_id


class EngineReactor:
    """submit/step/poll driver over one ``ServingEngine``.

    Single-threaded by contract: all calls must come from one thread
    (the gateway serialises engine access through its reactor loop).
    """

    def __init__(self, engine):
        self.engine = engine
        self._handles: Dict[int, RequestHandle] = {}
        engine.start_online()

    # ---- submission ---------------------------------------------------
    def submit(self, session: Session,
               arrival_s: Optional[float] = None) -> RequestHandle:
        """Register a live session.  ``arrival_s`` (engine clock) defaults
        to *now* — the open-loop driver controls offered load by when it
        calls submit, not by pre-staged ``ready_s`` offsets."""
        session.ready_s = (self.engine.clock() if arrival_s is None
                           else arrival_s)
        self.engine.attach(session)
        handle = RequestHandle(session=session)
        self._handles[session.session_id] = handle
        return handle

    # ---- stepping -----------------------------------------------------
    def step(self) -> List[TokenEvent]:
        """Advance the engine exactly one cycle and route the emitted
        tokens to their handles.  Returns the cycle's events (callers
        that stream don't need to poll).

        Completed sessions are detached from the engine registry and
        this reactor's handle table on their ``session_end`` event, so
        a long-lived server's per-cycle cost and memory stay bounded by
        the *live* session count (the caller's handle object keeps
        working — poll reads the session state it already holds)."""
        events = self.engine.step()
        for ev in events:
            handle = self._handles.get(ev.session_id)
            if handle is not None:
                handle.events.append(ev)
            if ev.session_end:
                self.engine.detach(ev.session_id)
                self._handles.pop(ev.session_id, None)
        return events

    @property
    def did_work(self) -> bool:
        return self.engine.last_step_did_work

    def pending(self) -> bool:
        return self.engine.pending()

    # ---- handle-side --------------------------------------------------
    def poll(self, handle: RequestHandle) -> HandleStatus:
        return _STATE_TO_STATUS[handle.session.state]

    def take_events(self, handle: RequestHandle) -> List[TokenEvent]:
        out = list(handle.events)
        handle.events.clear()
        return out

    def resume(self, handle: RequestHandle) -> None:
        """Tool-completion hook: re-arm a TOOL_WAIT session for its next
        turn (the gateway owns the tool-wait clock)."""
        self.engine.resume_session(handle.session_id)

    def park(self, handle: RequestHandle) -> None:
        """Release the session's KV slot while it waits on a tool (the
        under-pressure policy); the resume path restores it losslessly."""
        self.engine.park_session(handle.session_id)

    def abort(self, handle: RequestHandle, reason: str = "aborted") -> bool:
        """Quarantine one session: reclaim its slot/pages and emit its
        terminal error event (delivered by the next ``step()``).  False
        when the session already reached a terminal state — abort races
        against completion are benign."""
        return self.engine.abort_session(handle.session_id, reason)

    # ---- convenience --------------------------------------------------
    def drain(self, max_wall_s: float = 300.0,
              idle_sleep_s: float = 0.0005) -> List[TokenEvent]:
        """Step until every submitted session finishes (bounded by wall
        clock).  Test/benchmark convenience — the gateway runs its own
        async loop instead."""
        out: List[TokenEvent] = []
        t0 = time.perf_counter()
        while self.pending() and time.perf_counter() - t0 < max_wall_s:
            out.extend(self.step())
            if not self.did_work:
                time.sleep(idle_sleep_s)
        self.engine.flush()
        return out
