"""ToolBench-like agent workload generators (paper §IV-A, Table I).

Two paradigms, with token distributions matching Table I:

  ReAct           cold 2.5k-3.5k | resume 30-127 (avg 56)  | decode 27-127
  Plan-and-Execute cold 2.5k-3.5k | resume 125-421 (avg 251)| decode 33-141

``token_scale`` shrinks every length by a constant factor so the same
session *structure* runs against CPU mini-models in bounded wall time
(DESIGN.md §7.3); scale=1.0 reproduces Table I exactly (validated by
benchmarks/table1_tokens.py).

Sessions within a run share one of ``num_system_prompts`` system prompts
(tool specs are per-deployment, not per-session) — this is what makes
cross-session prefix caching meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serving.request import AgentTurn, Session


@dataclasses.dataclass
class WorkloadSpec:
    name: str
    cold_range: tuple = (2500, 3500)
    resume_range: tuple = (30, 127)
    resume_mean: float = 56.0
    decode_range: tuple = (27, 127)
    decode_mean: float = 40.0
    turns_range: tuple = (3, 7)
    tool_latency_range_s: tuple = (0.05, 0.3)


REACT = WorkloadSpec(
    name="react",
    resume_range=(30, 127), resume_mean=56.0,
    decode_range=(27, 127), decode_mean=40.0,
    turns_range=(4, 8),
)

PLAN_EXECUTE = WorkloadSpec(
    name="plan_execute",
    resume_range=(125, 421), resume_mean=251.0,
    decode_range=(33, 141), decode_mean=60.0,
    turns_range=(2, 5),
)

SPECS = {"react": REACT, "plan_execute": PLAN_EXECUTE}


def _clipped_lognormal(rng, lo, hi, mean, size=None):
    """Right-skewed lengths in [lo, hi] with the requested mean — matches
    the 'short typical, long tail' shape of tool outputs."""
    mu = np.log(max(mean - lo, 1.0))
    x = lo + np.exp(rng.normal(mu, 0.55, size=size))
    return np.clip(np.round(x), lo, hi).astype(int)


def make_session(session_id: int, spec: WorkloadSpec, rng: np.random.Generator,
                 vocab_size: int, *, token_scale: float = 1.0,
                 system_prompt: Optional[np.ndarray] = None) -> Session:
    def scale(n):
        return max(1, int(round(n * token_scale)))

    cold_len = scale(rng.integers(*spec.cold_range))
    shared_len = 0
    if system_prompt is not None:
        sys_part = system_prompt[:cold_len]
        shared_len = len(sys_part)
        user_part = rng.integers(0, vocab_size, size=max(cold_len // 8, 1))
        cold_tokens = np.concatenate([sys_part, user_part]).astype(np.int32)
    else:
        cold_tokens = rng.integers(0, vocab_size, size=cold_len,
                                   dtype=np.int32)

    n_turns = int(rng.integers(*spec.turns_range))
    turns: List[AgentTurn] = [AgentTurn(
        prefill_tokens=cold_tokens,
        decode_len=scale(_clipped_lognormal(
            rng, *spec.decode_range, spec.decode_mean)),
        tool_latency_s=float(rng.uniform(*spec.tool_latency_range_s)),
    )]
    for _ in range(n_turns - 1):
        r_len = scale(_clipped_lognormal(
            rng, *spec.resume_range, spec.resume_mean))
        turns.append(AgentTurn(
            prefill_tokens=rng.integers(0, vocab_size, size=r_len,
                                        dtype=np.int32),
            decode_len=scale(_clipped_lognormal(
                rng, *spec.decode_range, spec.decode_mean)),
            tool_latency_s=float(rng.uniform(*spec.tool_latency_range_s)),
        ))
    return Session(session_id=session_id, turns=turns, workload=spec.name,
                   shared_prefix_len=shared_len)


def make_workload(num_sessions: int, *, workload: str = "react",
                  vocab_size: int = 512, token_scale: float = 1.0,
                  num_system_prompts: int = 1, seed: int = 0,
                  stagger_s: float = 0.15) -> List[Session]:
    """Sessions arrive staggered by ``stagger_s`` (multi-agent burst)."""
    rng = np.random.default_rng(seed)
    spec = SPECS[workload]
    max_cold = int(round(spec.cold_range[1] * token_scale)) + 1
    prompts = [rng.integers(0, vocab_size, size=max_cold, dtype=np.int32)
               for _ in range(num_system_prompts)]
    sessions = []
    for i in range(num_sessions):
        s = make_session(i, spec, rng, vocab_size, token_scale=token_scale,
                         system_prompt=prompts[i % num_system_prompts])
        s.ready_s = i * stagger_s
        sessions.append(s)
    return sessions


# ---------------------------------------------------------------------------
# open-loop arrival processes (DESIGN.md §6)
# ---------------------------------------------------------------------------

def poisson_arrivals(rate_rps: float, n: int, seed: int = 0,
                     start_s: float = 0.0) -> np.ndarray:
    """``n`` seeded-deterministic Poisson arrival times at ``rate_rps``
    requests/s.  Open-loop: arrivals do not wait for service, which is
    what creates the HOL-blocking queueing regime the paper studies —
    a closed cohort can never over-subscribe the engine."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return start_s + np.cumsum(gaps)


def save_arrival_trace(path: str, arrivals: np.ndarray) -> None:
    """One arrival timestamp (seconds, float) per line."""
    with open(path, "w") as f:
        for t in np.asarray(arrivals, dtype=float):
            f.write(f"{t:.9f}\n")


def load_arrival_trace(path: str) -> np.ndarray:
    """Replay a recorded arrival trace (one float per line; blank lines
    and ``#`` comments ignored).  Times must be non-decreasing."""
    times = []
    with open(path) as f:
        for line in f:
            line = line.split("#", 1)[0].strip()
            if line:
                times.append(float(line))
    arr = np.asarray(times, dtype=float)
    if arr.size and np.any(np.diff(arr) < 0):
        raise ValueError(f"arrival trace {path} is not sorted")
    return arr


def make_open_loop_workload(num_sessions: int, *, workload: str = "react",
                            vocab_size: int = 512, token_scale: float = 1.0,
                            num_system_prompts: int = 1, seed: int = 0,
                            rate_rps: Optional[float] = None,
                            arrivals: Optional[np.ndarray] = None,
                            trace_path: Optional[str] = None):
    """Sessions with open-loop arrival times in ``ready_s``.

    Exactly one arrival source: ``rate_rps`` (seeded Poisson),
    ``arrivals`` (explicit times), or ``trace_path`` (trace-file
    replay).  Session *content* is drawn with the same generator as the
    closed-loop ``make_workload`` so Table-I distributions are
    preserved; determinism follows from (seed, arrival source)."""
    sources = sum(x is not None for x in (rate_rps, arrivals, trace_path))
    if sources != 1:
        raise ValueError("pass exactly one of rate_rps / arrivals / "
                         "trace_path")
    if rate_rps is not None:
        arrivals = poisson_arrivals(rate_rps, num_sessions, seed=seed)
    elif trace_path is not None:
        arrivals = load_arrival_trace(trace_path)
    arrivals = np.asarray(arrivals, dtype=float)
    if len(arrivals) < num_sessions:
        raise ValueError(f"need {num_sessions} arrivals, trace has "
                         f"{len(arrivals)}")
    sessions = make_workload(num_sessions, workload=workload,
                             vocab_size=vocab_size, token_scale=token_scale,
                             num_system_prompts=num_system_prompts,
                             seed=seed, stagger_s=0.0)
    for s, t in zip(sessions, arrivals):
        s.ready_s = float(t)
    return sessions


def table1_statistics(workload: str, n: int = 200, seed: int = 0):
    """Empirical token distribution for benchmarks/table1_tokens.py."""
    rng = np.random.default_rng(seed)
    spec = SPECS[workload]
    colds, resumes, decodes = [], [], []
    for i in range(n):
        s = make_session(i, spec, rng, vocab_size=512)
        colds.append(len(s.turns[0].prefill_tokens))
        for t in s.turns[1:]:
            resumes.append(len(t.prefill_tokens))
        for t in s.turns:
            decodes.append(t.decode_len)
    stats = {}
    for k, xs in [("cold_prefill", colds), ("resume_prefill", resumes),
                  ("decode", decodes)]:
        xs = np.asarray(xs)
        stats[k] = dict(min=int(xs.min()), max=int(xs.max()),
                        mean=float(xs.mean()))
    return stats
