from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.kvcache import KVCachePool  # noqa: F401
from repro.serving.metrics import ServingReport, SLOThresholds  # noqa: F401
from repro.serving.policies import POLICIES, PolicySpec  # noqa: F401
from repro.serving.workload import make_workload  # noqa: F401
