from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.faults import (ChaosRun, FaultPlan,  # noqa: F401
                                  FaultSpec, InjectedFault, SessionFault,
                                  drive_chaos)
from repro.serving.gateway import (AgentGateway, GatewayConfig,  # noqa: F401
                                   LiveSession, Rejected, drive_open_loop)
from repro.serving.kvcache import KVCachePool, KVExhausted  # noqa: F401
from repro.serving.metrics import (OpenLoopReport, ServingReport,  # noqa: F401
                                   SLOThresholds, build_open_loop_report)
from repro.serving.policies import POLICIES, PolicySpec  # noqa: F401
from repro.serving.reactor import (EngineReactor, HandleStatus,  # noqa: F401
                                   RequestHandle, TokenEvent)
from repro.serving.workload import (make_open_loop_workload,  # noqa: F401
                                    make_workload, poisson_arrivals)
