"""The AgentServe single-engine serving loop.

Execution model (DESIGN.md §2 — the TPU/JAX adaptation of the paper's
Execution Layer): the engine advances in *cycles*.  Each cycle runs at
most one batched decode step (all active sequences — continuous
batching) and an amount of prefill work bounded by the current slot
partition: the decode reservation R(t) of the cycle token budget C
protects decode cadence, and the complement (C - R) is the cold-prefill
chunk processed that cycle.  Resume prefills within B_prefill(t) are
fused into the decode stream (Q_D); cold prefills only ever run from
the prefill stream (Q_P) — the isolation invariant.

TPOT mapping: on GPU, shrinking decode's SM share inflates the decode
kernel's own latency; in the temporal adaptation the decode kernel time
is constant but the *inter-emission gap* (cycle time) grows with the
co-scheduled prefill chunk.  The scheduler therefore measures TPOT as
the gap between consecutive decode-step completions — the quantity the
user actually experiences (and what Fig 2 plots).

Slot semantics: ``SlotManager`` holds pre-compiled prefill executables
keyed by decode-reservation level; binding level R dispatches the
(C - R)-token chunk executable.  With ``preestablish=False`` (the
No-Green ablation) the executable is rebuilt on demand inside the
serving path, reproducing the paper's on-demand-allocation cost.

Executable shapes are always drawn from the pre-established grid (slot
chunks + power-of-two resume buckets); shorter real work is padded to
the executable's shape and masked — shape-stable dispatch is precisely
the Green-Context-analogue discipline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.admission import AdmissionQueues, Job
from repro.core.phases import Phase, PhaseThresholds, classify
from repro.core.scheduler import SchedulerConfig, TPOTScheduler
from repro.core.slots import SlotManager
from repro.models import forward_decode, forward_prefill
from repro.serving.kvcache import KVCachePool
from repro.serving.metrics import ServingReport, SLOThresholds, build_report
from repro.serving.policies import PolicySpec
from repro.serving.request import Session, SessionState


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 8
    max_seq: int = 1024
    cycle_budget: int = 320          # C: tokens of work per cycle
    granularity: int = 32            # g: slot granularity (C/g = 10 slots)
    moe_mode: str = "dense"          # tiny models on CPU: dense is faster
    control_interval_s: float = 0.25
    tpot_slo_ms: float = 50.0
    b_min: int = 32
    b_max: int = 512
    b_init: int = 128
    delta_b: int = 32
    max_wall_s: float = 300.0


def _resume_buckets(cfg: EngineConfig) -> List[int]:
    out, b = [], cfg.granularity
    while b < cfg.b_max:
        out.append(b)
        b *= 2
    out.append(cfg.b_max)
    return out


# Shared across engine instances for the same (model, shapes): baselines
# and AgentServe then dispatch the *same* compiled code, isolating the
# scheduling policy as the only varying factor.
_EXEC_CACHE: Dict[Tuple, Tuple[Callable, Callable]] = {}


def _raw_fns(mcfg: ModelConfig, moe_mode: str):
    def decode_step(params, cache, tokens, lengths):
        logits, new_cache, _ = forward_decode(
            params, mcfg, tokens, cache, lengths, moe_mode=moe_mode)
        return logits, new_cache

    def prefill_step(params, cache, tokens, slot, length, logit_idx):
        sub = jax.tree.map(
            lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
            cache)
        logits, sub2, _ = forward_prefill(
            params, mcfg, tokens, sub, length[None],
            moe_mode=moe_mode, logit_idx=logit_idx[None])
        new_cache = jax.tree.map(
            lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                full, s, slot, axis=1),
            cache, sub2)
        return logits[0], new_cache

    return decode_step, prefill_step


def get_executables(mcfg: ModelConfig, num_slots: int, max_seq: int,
                    moe_mode: str):
    key = (mcfg, num_slots, max_seq, moe_mode)
    if key not in _EXEC_CACHE:
        d, p = _raw_fns(mcfg, moe_mode)
        _EXEC_CACHE[key] = (jax.jit(d), jax.jit(p))
    return _EXEC_CACHE[key]


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, params, policy: PolicySpec,
                 engine_cfg: Optional[EngineConfig] = None,
                 dtype=jnp.float32):
        self.mcfg = model_cfg
        self.params = params
        self.policy = policy
        self.ecfg = engine_cfg or EngineConfig()
        self.pool = KVCachePool(model_cfg, self.ecfg.num_slots,
                                self.ecfg.max_seq, dtype)
        C, g = self.ecfg.cycle_budget, self.ecfg.granularity
        self.scheduler = TPOTScheduler(SchedulerConfig(
            total_resources=C, r_base=g, r_init=2 * g, delta_r=g,
            b_min=self.ecfg.b_min, b_max=self.ecfg.b_max,
            b_init=self.ecfg.b_init, delta_b=self.ecfg.delta_b,
            tpot_slo_ms=self.ecfg.tpot_slo_ms,
            control_interval_s=self.ecfg.control_interval_s))
        self.queues = AdmissionQueues(self.scheduler)
        self.thresholds = PhaseThresholds(resume_max_new=self.ecfg.b_max)

        self._decode_fn, self._prefill_fn = get_executables(
            model_cfg, self.ecfg.num_slots, self.ecfg.max_seq,
            self.ecfg.moe_mode)
        self.slots = SlotManager(
            C, g, self._build_slot, preestablish=policy.preestablish)
        self._warm_shared()

        # run-state
        self._t0 = time.perf_counter()
        self._last_decode_end: Optional[float] = None
        self.trace: List[Dict] = []       # per-cycle telemetry (Fig 2)

    # ------------------------------------------------------------------
    # executables & warmup
    # ------------------------------------------------------------------
    def _build_slot(self, level: int):
        """Slot executable for decode-reservation ``level``: the prefill
        chunk is C - level tokens.  Pre-establishing == compiling now;
        the No-Green path lands this cost inside the serving loop."""
        chunk = self.ecfg.cycle_budget - level
        if chunk <= 0:
            return {"chunk": 0, "fn": None}
        if self.policy.preestablish:
            fn = self._prefill_fn
        else:
            _, raw_p = _raw_fns(self.mcfg, self.ecfg.moe_mode)
            fn = jax.jit(raw_p)          # fresh cache -> real recompile
        self._warm_prefill(fn, chunk)
        return {"chunk": chunk, "fn": fn}

    def _warm_prefill(self, fn, chunk: int) -> None:
        toks = jnp.zeros((1, chunk), jnp.int32)
        lg, _ = fn(self.params, self.pool.cache, toks,
                   jnp.int32(0), jnp.int32(0), jnp.int32(chunk - 1))
        jax.block_until_ready(lg)

    def _warm_shared(self) -> None:
        lg, _ = self._decode_fn(
            self.params, self.pool.cache,
            jnp.zeros((self.ecfg.num_slots,), jnp.int32),
            jnp.zeros((self.ecfg.num_slots,), jnp.int32))
        jax.block_until_ready(lg)
        for b in _resume_buckets(self.ecfg):
            self._warm_prefill(self._prefill_fn, b)
        if not self.policy.chunk_by_slots and not self.policy.whole_prefill:
            self._warm_prefill(self._prefill_fn, self._fixed_chunk())

    def _fixed_chunk(self) -> int:
        g = self.ecfg.granularity
        c = int(self.policy.fixed_chunk_frac * self.ecfg.cycle_budget)
        return max(g, (c // g) * g)

    # ------------------------------------------------------------------
    # work execution
    # ------------------------------------------------------------------
    def _run_prefill_tokens(self, sess: Session, shape_len: int,
                            take: Optional[int] = None,
                            fn: Optional[Callable] = None) -> None:
        """Prefill up to ``take`` real tokens (default: fill the shape)
        of the session's current turn in an executable of token-shape
        ``shape_len`` — shorter work is padded and masked."""
        take = min(take if take is not None else shape_len, shape_len,
                   self._aligned_remaining(sess))
        if take <= 0:
            return
        turn = sess.current_turn
        toks = turn.prefill_tokens[sess.prefill_done: sess.prefill_done + take]
        pad = shape_len - take
        if pad:
            toks = np.concatenate([toks, np.zeros(pad, np.int32)])
        fn = fn or self._prefill_fn
        logits, new_cache = fn(
            self.params, self.pool.cache,
            jnp.asarray(toks[None], jnp.int32),
            jnp.int32(sess.slot), jnp.int32(self.pool.lengths[sess.slot]),
            jnp.int32(take - 1))
        logits = jax.block_until_ready(logits)
        self.pool.cache = new_cache
        self.pool.lengths[sess.slot] += take
        sess.prefill_done += take
        sess.cached_len = int(self.pool.lengths[sess.slot])

        # prefix registration at the shared-prompt boundary (cold only)
        if (sess.turn_idx == 0 and sess.shared_prefix_len > 0
                and sess.cached_len == sess.shared_prefix_len
                and sess.prefill_done == sess.shared_prefix_len):
            self.pool.register_prefix(
                sess.slot, turn.prefill_tokens[:sess.shared_prefix_len])

        if sess.remaining_prefill == 0:
            self._finish_prefill(sess, np.asarray(logits))

    def _aligned_remaining(self, s: Session) -> int:
        """Remaining prefill, capped at the shared-prefix boundary so the
        prefix snapshot is taken at exactly that length."""
        rem = s.remaining_prefill
        if (s.turn_idx == 0 and s.prefill_done < s.shared_prefix_len
                and s.cached_len < s.shared_prefix_len):
            rem = min(rem, s.shared_prefix_len - s.prefill_done)
        return rem

    def _finish_prefill(self, sess: Session, last_logits: np.ndarray) -> None:
        now = self._clock()
        sess.last_token = int(last_logits.argmax())
        sess.first_token_s.append(now)
        sess.token_times_s.append(now)
        sess.decoded = 1
        self._after_token(sess, now)

    def _decode_step(self, active: Sequence[Session]) -> None:
        tokens = np.zeros((self.ecfg.num_slots,), np.int32)
        mask = np.zeros((self.ecfg.num_slots,), bool)
        for s in active:
            tokens[s.slot] = s.last_token
            mask[s.slot] = True
        logits, new_cache = self._decode_fn(
            self.params, self.pool.cache, jnp.asarray(tokens),
            self.pool.lengths_device())
        logits = np.asarray(jax.block_until_ready(logits))
        self.pool.commit(new_cache, mask)
        now = self._clock()
        if self._last_decode_end is not None:
            self.scheduler.record_decode_step(now - self._last_decode_end)
        self._last_decode_end = now
        for s in active:
            self.pool.lengths[s.slot] += 1
            s.cached_len = int(self.pool.lengths[s.slot])
            s.last_token = int(logits[s.slot].argmax())
            s.token_times_s.append(now)
            s.decoded += 1
            self._after_token(s, now)

    def _after_token(self, sess: Session, now: float) -> None:
        turn = sess.current_turn
        if sess.decoded < turn.decode_len:
            sess.state = SessionState.DECODING
            return
        if sess.turn_idx + 1 >= len(sess.turns):
            sess.state = SessionState.FINISHED
            self.pool.free(sess.slot)
            return
        sess.turn_idx += 1
        sess.prefill_done = 0
        sess.decoded = 0
        sess.state = SessionState.TOOL_CALL
        sess.ready_s = now + sess.turns[sess.turn_idx - 1].tool_latency_s

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _admit(self, sessions: Sequence[Session]) -> None:
        now = self._clock()
        for s in sessions:
            if s.state == SessionState.WAITING_PREFILL and s.ready_s <= now:
                if self.pool.free_slots == 0:
                    continue  # backpressure: retry next cycle
                s.slot = self.pool.alloc()
                self._maybe_restore_prefix(s)
                self._submit(s, now)
            elif s.state == SessionState.TOOL_CALL and s.ready_s <= now:
                self._submit(s, now)

    def _maybe_restore_prefix(self, s: Session) -> None:
        if s.shared_prefix_len <= 0:
            return
        entry = self.pool.lookup(
            s.turns[0].prefill_tokens[:s.shared_prefix_len])
        if entry is not None:
            self.pool.restore_prefix(s.slot, entry)
            s.cached_len = entry.length
            s.prefill_done = entry.length

    def _submit(self, s: Session, now: float) -> None:
        s.arrival_s = now
        s.request_arrivals.append(now)
        s.state = SessionState.PREFILLING
        new_len = s.remaining_prefill
        if self.policy.split_phases:
            phase = classify(s.total_prompt_len, s.cached_len, new_len,
                             self.thresholds)
        else:
            phase = Phase.COLD_PREFILL  # phase-blind baseline
        job = Job(session_id=s.session_id, phase=phase, new_len=new_len,
                  arrival_s=now)
        if self.policy.resume_to_decode_queue:
            self.queues.enqueue(job)
        else:
            self.queues.q_prefill.append(job)

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    def run(self, sessions: Sequence[Session],
            thresholds: Optional[SLOThresholds] = None) -> ServingReport:
        by_id = {s.session_id: s for s in sessions}
        self._t0 = time.perf_counter()
        next_ctrl = self.ecfg.control_interval_s
        policy, ecfg = self.policy, self.ecfg
        C = ecfg.cycle_budget

        if not policy.adaptive:
            self.scheduler.state.r_min = max(
                ecfg.granularity,
                int(policy.static_r_frac * C) // ecfg.granularity
                * ecfg.granularity)

        while any(s.state != SessionState.FINISHED for s in sessions):
            now = self._clock()
            if now > ecfg.max_wall_s:
                break
            self._admit(sessions)

            # ---- control update + slot rebind (Algorithm 1) ----------
            if now >= next_ctrl:
                if policy.adaptive:
                    self.scheduler.update()
                next_ctrl = now + ecfg.control_interval_s
            slot_exec, level = self.slots.bind(self.scheduler.state.r_min)

            active = [s for s in sessions if s.state == SessionState.DECODING]
            q_d, q_p = self.queues.occupancy()

            did_work = False
            # ---- decode stream ----------------------------------------
            allow_decode = policy.protect_decode or q_p == 0
            if active and allow_decode:
                self._decode_step(active)
                did_work = True
            elif not active:
                self._last_decode_end = None

            # ---- resume prefills fused into the decode stream --------
            if policy.resume_to_decode_queue and self.queues.q_decode:
                job = self.queues.q_decode.popleft()
                s = by_id[job.session_id]
                if s.state == SessionState.PREFILLING:
                    bucket = self._bucket_for(max(s.remaining_prefill, 1))
                    self._run_prefill_tokens(s, bucket)
                    did_work = True
                    if s.state == SessionState.PREFILLING:
                        self.queues.q_decode.append(job)  # continue next cycle

            # ---- prefill stream (cold / over-budget / phase-blind) ----
            did_work |= self._prefill_stream_step(by_id, slot_exec)
            if not active and self.queues.q_prefill and policy.chunk_by_slots:
                # opportunistic reclaim (paper §III-C): no decode demand,
                # so the prefill stream claims the full cycle budget
                full_exec, _ = self.slots.bind(self.scheduler.cfg.r_base)
                for _ in range(3):
                    if (self.queues.q_prefill
                            and not any(s.state == SessionState.DECODING
                                        for s in sessions)):
                        self._prefill_stream_step(by_id, full_exec)
                    else:
                        break

            self.trace.append(dict(
                t=self._clock(), tpot_ms=self.scheduler.state.tpot_step_ms,
                r_min=self.scheduler.state.r_min,
                b_prefill=self.scheduler.state.b_prefill,
                q_d=q_d, q_p=q_p, active=len(active)))
            if not did_work:
                time.sleep(0.0005)

        wall = self._clock()
        extra = {
            "rebinds": float(self.slots.stats.rebinds),
            "mean_rebind_us": self.slots.stats.mean_rebind_us,
            "slot_misses": float(self.slots.stats.misses),
            "prefix_hits": float(self.pool.stats["prefix_hits"]),
        }
        return build_report(policy.name, list(sessions), wall, thresholds,
                            extra)

    # ------------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for b in _resume_buckets(self.ecfg):
            if b >= n:
                return b
        return _resume_buckets(self.ecfg)[-1]

    def _prefill_stream_step(self, by_id, slot_exec) -> bool:
        if not self.queues.q_prefill:
            return False
        job = self.queues.q_prefill[0]
        s = by_id[job.session_id]
        if s.state != SessionState.PREFILLING:
            self.queues.q_prefill.popleft()
            return False
        if s.remaining_prefill == 0:
            # unreachable with our workloads (shared prefix < full prompt);
            # would require a last-token re-run that is unsafe for SSM state
            raise RuntimeError("fully-cached request needs >=1 new token")
        if self.policy.whole_prefill:
            # llama.cpp-style: run the entire prompt to completion now
            bucket = max(_resume_buckets(self.ecfg))
            while s.state == SessionState.PREFILLING:
                self._run_prefill_tokens(s, bucket)
            self.queues.q_prefill.popleft()
            return True
        if self.policy.chunk_by_slots:
            chunk, fn = slot_exec["chunk"], slot_exec["fn"]
        else:
            chunk, fn = self._fixed_chunk(), None
        if chunk <= 0:
            return False
        self._run_prefill_tokens(s, chunk, fn=fn)
        if s.state != SessionState.PREFILLING:
            self.queues.q_prefill.popleft()
        return True

