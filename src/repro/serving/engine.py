"""The AgentServe single-engine serving loop.

Execution model (DESIGN.md §2 — the TPU/JAX adaptation of the paper's
Execution Layer): the engine advances in *cycles*.  Each cycle runs at
most one batched decode step (all active sequences — continuous
batching) and an amount of prefill work bounded by the current slot
partition: the decode reservation R(t) of the cycle token budget C
protects decode cadence, and the complement (C - R) is the cold-prefill
chunk processed that cycle.  Resume prefills within B_prefill(t) are
fused into the decode stream (Q_D); cold prefills only ever run from
the prefill stream (Q_P) — the isolation invariant.

Plan → execute (DESIGN.md §9): the engine makes **no scheduling
decisions**.  Each ``step()`` asks its ``CyclePlanner`` (a pure
strategy over an immutable ``EngineView`` — ``core/planner.py``) for a
declarative ``CyclePlan``, then the ``Dispatcher`` carries the plan out
against the warmed executables and the KV pool.  Every executed plan is
journaled; replaying a journal through the same dispatcher reproduces a
run's token events deterministically.

TPOT mapping: on GPU, shrinking decode's SM share inflates the decode
kernel's own latency; in the temporal adaptation the decode kernel time
is constant but the *inter-emission gap* (cycle time) grows with the
co-scheduled prefill chunk.  The scheduler therefore measures TPOT as
the gap between consecutive decode-step completions — the quantity the
user actually experiences (and what Fig 2 plots).

Device-resident hot path (DESIGN.md §3): the decode stream never syncs
per token.  Greedy sampling, the length increment and the active-lane
cache merge are folded into one jitted step (``forward_decode_fused``),
so ``last_token``/``lengths``/``active`` live as device arrays between
steps; the host only blocks at *flush points* (control-interval
boundaries, burst completions, and every ``telemetry_sample_steps``
steps), where it records the aggregate inter-emission gap with the step
count — the same TPOT quantity, measured at a sampled cadence.  When
both queues are empty and no control update is due, up to K decode
iterations are fused into one ``lax.scan`` *megastep* executable drawn
from a pre-established grid (the same Green-Context shape-stable
discipline as the prefill slots).  Resume prefills from Q_D are packed
M-at-a-time into one [M, bucket] batched executable.

Slot semantics: ``SlotManager`` holds pre-compiled prefill executables
keyed by decode-reservation level; binding level R dispatches the
(C - R)-token chunk executable.  With ``preestablish=False`` (the
No-Green ablation) the executable is rebuilt on demand inside the
serving path, reproducing the paper's on-demand-allocation cost.

Executable shapes are always drawn from the pre-established grid (slot
chunks + power-of-two resume buckets + megastep levels + resume batch
sizes); shorter real work is padded to the executable's shape and
masked — shape-stable dispatch is precisely the Green-Context-analogue
discipline.

Reactor surface (DESIGN.md §6): the engine is *steppable* — ``attach``
registers a session, ``step()`` runs exactly one cycle and returns the
``TokenEvent``s it emitted, and the closed-loop ``run()`` is
reimplemented as attach-all + step-until-done.  Online drivers
(``serving/reactor.py``, ``serving/gateway.py``) use the same ``step``
plus ``resume_session``/``park_session`` for gateway-clocked tool
waits.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.admission import AdmissionQueues, Job
from repro.core.phases import Phase, PhaseThresholds
from repro.core.planner import (Admission, ColdOp, CyclePlan, CyclePlanner,
                                CycleRecord, EngineView, JobView,
                                PlanJournal, ResumePlan, SessionView)
from repro.core.scheduler import SchedulerConfig, TPOTScheduler
from repro.core.slots import SlotManager
from repro.models import (POSITIONAL_CACHE_KEYS, forward_decode,
                          forward_decode_fused, forward_decode_megastep,
                          forward_prefill, forward_resume_batch)
from repro.serving.faults import SessionFault
from repro.serving.kvcache import KVExhausted, make_pool, prefix_key
from repro.serving.metrics import ServingReport, SLOThresholds, build_report
from repro.serving.policies import PolicySpec, make_planner
from repro.serving.reactor import TokenEvent
from repro.serving.request import Session, SessionState
from repro.serving.telemetry import RegistryDict, Telemetry


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 8
    max_seq: int = 1024
    cycle_budget: int = 320          # C: tokens of work per cycle
    granularity: int = 32            # g: slot granularity (C/g = 10 slots)
    moe_mode: str = "dense"          # tiny models on CPU: dense is faster
    control_interval_s: float = 0.25
    tpot_slo_ms: float = 50.0
    b_min: int = 32
    b_max: int = 512
    b_init: int = 128
    delta_b: int = 32
    max_wall_s: float = 300.0
    # --- device-resident hot path (DESIGN.md §3) ----------------------
    megastep_max: int = 8            # K cap for fused decode megasteps
    megastep_unit: int = 2           # megastep grid granularity (≥2)
    resume_batch_max: int = 4        # M cap for batched resume prefill
    telemetry_sample_steps: int = 32  # decode flush cadence (host sync)
    # --- cache-aware prefill hot path (DESIGN.md §4) ------------------
    cold_batch_max: int = 4          # M cap for packed cold prefills
    autotune_chunks: bool = True     # measure chunk tok/s at slot warmup
    prefill_tile: int = 128          # kernel KV tile (telemetry estimate)
    # --- paged KV pool (DESIGN.md §8) ---------------------------------
    kv_pages: int = 0                # paged layout: usable page count
    #                                  (0 = slab-capacity parity:
    #                                  num_slots * max_seq / page_size)
    # --- online reactor (DESIGN.md §6) --------------------------------
    trace_max: int = 200_000         # per-cycle telemetry cap (long-run
    #                                  gateway processes must not grow
    #                                  the trace without bound)
    record_events: bool = False      # run(): keep TokenEvents in
    #                                  engine.event_log (regression tests)
    # --- plan journal (DESIGN.md §9) ----------------------------------
    journal_max: int = 200_000       # executed CyclePlans kept for
    #                                  replay / per-policy reporting
    # --- fault domains (DESIGN.md §10) --------------------------------
    kv_defer_limit: int = 8          # per-session KVExhausted deferrals
    #                                  tolerated before the session is
    #                                  aborted (the back-off valve that
    #                                  frees pages under hard pressure)
    # --- telemetry (DESIGN.md §11) ------------------------------------
    telemetry: bool = True           # span tracing + latency histograms
    #                                  (the metrics registry — the stats
    #                                  surface — is always on)
    spans_max: int = 200_000         # completed-span ring capacity


def _resume_buckets(cfg: EngineConfig) -> List[int]:
    out, b = [], cfg.granularity
    while b < cfg.b_max:
        out.append(b)
        b *= 2
    out.append(cfg.b_max)
    return out


@dataclasses.dataclass
class HotPathExecutables:
    """One compiled-executable set per (model, shapes) key.

    ``fused``/``resume`` and every megastep executable *donate* their
    cache argument: the previous cache buffer is consumed by the call,
    which lets XLA update KV rows in place instead of copying the full
    cache per step.  Callers must immediately replace their cache
    reference with the returned one (``ServingEngine`` does)."""
    decode: Callable       # legacy per-step decode returning logits
    prefill: Callable      # batch-1 chunk prefill
    fused: Callable        # device-resident decode step (donates cache)
    resume: Callable       # batched resume prefill (donates cache)
    megastep: Callable[[int], Callable]   # K -> jitted scan executable


# Shared across engine instances for the same (model, shapes): baselines
# and AgentServe then dispatch the *same* compiled code, isolating the
# scheduling policy as the only varying factor.
_EXEC_CACHE: Dict[Tuple, HotPathExecutables] = {}


def _is_positional_layer(layer) -> bool:
    return set(layer) <= POSITIONAL_CACHE_KEYS


def _raw_fns(mcfg: ModelConfig, moe_mode: str):
    """Hot-path step functions.  Under the paged layout every signature
    gains a trailing ``bt`` ([B, P_max] block tables) and the per-slot
    gather/scatter only touches *stateful* leaves — positional leaves
    are the shared page arena, addressed through the tables."""
    if mcfg.kv_layout == "paged":
        return _raw_fns_paged(mcfg, moe_mode)

    def decode_step(params, cache, tokens, lengths):
        logits, new_cache, _ = forward_decode(
            params, mcfg, tokens, cache, lengths, moe_mode=moe_mode)
        return logits, new_cache

    def prefill_step(params, cache, tokens, slot, length, logit_idx):
        sub = jax.tree.map(
            lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
            cache)
        logits, sub2, _ = forward_prefill(
            params, mcfg, tokens, sub, length[None],
            moe_mode=moe_mode, logit_idx=logit_idx[None])
        new_cache = jax.tree.map(
            lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                full, s, slot, axis=1),
            cache, sub2)
        return logits[0], new_cache

    def fused_step(params, cache, tokens, lengths, active):
        return forward_decode_fused(params, mcfg, tokens, cache, lengths,
                                    active, moe_mode=moe_mode)

    def mega_step(params, cache, tokens, lengths, active, *, num_steps):
        return forward_decode_megastep(
            params, mcfg, tokens, cache, lengths, active,
            num_steps=num_steps, moe_mode=moe_mode)

    def resume_step(params, cache, tokens, slots, lengths, logit_idx):
        return forward_resume_batch(params, mcfg, tokens, cache, slots,
                                    lengths, logit_idx, moe_mode=moe_mode)

    return decode_step, prefill_step, fused_step, mega_step, resume_step


def _raw_fns_paged(mcfg: ModelConfig, moe_mode: str):
    def decode_step(params, cache, tokens, lengths, bt):
        logits, new_cache, _ = forward_decode(
            params, mcfg, tokens, cache, lengths, moe_mode=moe_mode,
            block_tables=bt)
        return logits, new_cache

    def prefill_step(params, cache, tokens, slot, length, logit_idx, bt):
        sub = {name: (layer if _is_positional_layer(layer) else
                      {k: jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=1)
                       for k, v in layer.items()})
               for name, layer in cache.items()}
        logits, sub2, _ = forward_prefill(
            params, mcfg, tokens, sub, length[None],
            moe_mode=moe_mode, logit_idx=logit_idx[None],
            block_tables=jax.lax.dynamic_slice_in_dim(bt, slot, 1, axis=0))
        new_cache = {
            name: (sub2[name] if _is_positional_layer(layer) else
                   {k: jax.lax.dynamic_update_slice_in_dim(
                       v, sub2[name][k], slot, axis=1)
                    for k, v in layer.items()})
            for name, layer in cache.items()}
        return logits[0], new_cache

    def fused_step(params, cache, tokens, lengths, active, bt):
        return forward_decode_fused(params, mcfg, tokens, cache, lengths,
                                    active, moe_mode=moe_mode,
                                    block_tables=bt)

    def mega_step(params, cache, tokens, lengths, active, bt, *, num_steps):
        return forward_decode_megastep(
            params, mcfg, tokens, cache, lengths, active,
            num_steps=num_steps, moe_mode=moe_mode, block_tables=bt)

    def resume_step(params, cache, tokens, slots, lengths, logit_idx, bt):
        return forward_resume_batch(params, mcfg, tokens, cache, slots,
                                    lengths, logit_idx, moe_mode=moe_mode,
                                    block_tables=bt)

    return decode_step, prefill_step, fused_step, mega_step, resume_step


def get_executables(mcfg: ModelConfig, num_slots: int, max_seq: int,
                    moe_mode: str) -> HotPathExecutables:
    key = (mcfg, num_slots, max_seq, moe_mode)
    if key not in _EXEC_CACHE:
        d, p, f, m, r = _raw_fns(mcfg, moe_mode)
        mega_jits: Dict[int, Callable] = {}

        def megastep(num_steps: int) -> Callable:
            if num_steps not in mega_jits:
                mega_jits[num_steps] = jax.jit(
                    functools.partial(m, num_steps=num_steps),
                    donate_argnums=(1,))
            return mega_jits[num_steps]

        _EXEC_CACHE[key] = HotPathExecutables(
            decode=jax.jit(d),
            prefill=jax.jit(p),
            fused=jax.jit(f, donate_argnums=(1,)),
            resume=jax.jit(r, donate_argnums=(1,)),
            megastep=megastep)
    return _EXEC_CACHE[key]


def _plan_kind(plan: CyclePlan) -> str:
    """Dispatch-kind label for the cycle span: the streams the plan
    touches, joined (a fused cycle reads e.g. "mega+resume")."""
    parts = []
    if plan.decode is not None:
        parts.append("mega" if plan.decode.megastep_target > 0
                     else "decode")
    if plan.resume is not None:
        parts.append("resume")
    if plan.prefill:
        parts.append("prefill")
    if plan.admissions and not parts:
        parts.append("admit")
    return "+".join(parts) or "idle"


def _planned_tokens(plan: CyclePlan) -> int:
    """Token volume the plan *intended* — compared against the dispatch
    counters in the cycle span (planned vs actual drift is the clamp /
    divergence signal)."""
    total = 0
    if plan.decode is not None:
        total += max(1, plan.decode.megastep_target) * \
            len(plan.decode.session_ids)
    if plan.resume is not None:
        total += plan.resume.bucket * len(plan.resume.session_ids)
    for op in plan.prefill:
        if op.kind == "pack":
            total += op.shape * len(op.session_ids)
        else:
            total += op.shape * op.reps
    return total


@dataclasses.dataclass
class CycleOutcome:
    """What one dispatched cycle observably did (telemetry feed)."""
    did_work: bool = False
    q_d: int = 0
    q_p: int = 0
    q_p_cold: int = 0                # cold-phase jobs in Q_P
    q_p_resume: int = 0              # over-budget resumes re-routed to Q_P
    active: int = 0


class Dispatcher:
    """Carries a ``CyclePlan`` out against the engine's warmed
    executables and KV pool — all mechanism, no decisions.  The only
    choices made here are *safety clamps* (burst/capacity bounds on the
    megastep K, free-slot checks) that keep a diverged or replayed plan
    from corrupting state."""

    def __init__(self, engine: "ServingEngine"):
        self.eng = engine

    def execute(self, plan: CyclePlan, now: float) -> CycleOutcome:
        eng = self.eng
        out = CycleOutcome()
        for sid in plan.preempt:
            eng._preempt_prefill(sid)
        for adm in plan.admissions:
            eng._exec_admission(adm, now)
        out.q_d, out.q_p = eng.queues.occupancy()
        out.q_p_cold = sum(1 for j in eng.queues.q_prefill
                           if j.phase == Phase.COLD_PREFILL)
        out.q_p_resume = out.q_p - out.q_p_cold
        out.active = sum(1 for s in eng._sessions.values()
                         if s.state == SessionState.DECODING)
        slot_exec = None
        if plan.slot_level > 0:
            slot_exec, _ = eng.slots.bind(plan.slot_level)

        # ---- decode stream ----------------------------------------
        if plan.decode is not None:
            active = [eng._sessions[sid] for sid in plan.decode.session_ids
                      if sid in eng._sessions
                      and eng._sessions[sid].state == SessionState.DECODING]
            if active:
                eng._decode_dispatch(active, plan.decode.megastep_target)
                out.did_work = True
        elif plan.flush_idle:
            eng._flush_decode()
            eng._window_t0 = None

        # ---- resume prefills fused into the decode stream --------
        if plan.resume is not None:
            out.did_work |= eng._exec_resume(plan.resume)

        # ---- prefill stream (cold / over-budget / phase-blind) ----
        eng._drop_stale_prefill_heads()
        for op in plan.prefill:
            if op.reclaim and any(
                    s.state == SessionState.DECODING
                    for s in eng._sessions.values()):
                break                # decode demand appeared mid-cycle
            out.did_work |= eng._exec_cold_op(op, slot_exec)

        for sid in plan.unsuspend:
            eng._unsuspend_prefill(sid, now)
        return out


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, params, policy,
                 engine_cfg: Optional[EngineConfig] = None,
                 dtype=jnp.float32):
        self.mcfg = model_cfg
        self.params = params
        # ``policy`` may be a PolicySpec (resolved through the planner
        # registry), a policy name, or a ready CyclePlanner instance
        # (e.g. ReplayPlanner).  The spec remains the construction-time
        # config: which executable shapes to warm, pre-establish or not.
        self.planner: CyclePlanner = make_planner(policy)
        self.policy: PolicySpec = self.planner.spec
        policy = self.policy
        self.ecfg = engine_cfg or EngineConfig()
        self._paged = model_cfg.kv_layout == "paged"
        self.pool = make_pool(model_cfg, self.ecfg.num_slots,
                              self.ecfg.max_seq, dtype,
                              num_pages=self.ecfg.kv_pages)
        C, g = self.ecfg.cycle_budget, self.ecfg.granularity
        self.scheduler = TPOTScheduler(SchedulerConfig(
            total_resources=C, r_base=g, r_init=2 * g, delta_r=g,
            b_min=self.ecfg.b_min, b_max=self.ecfg.b_max,
            b_init=self.ecfg.b_init, delta_b=self.ecfg.delta_b,
            tpot_slo_ms=self.ecfg.tpot_slo_ms,
            control_interval_s=self.ecfg.control_interval_s))
        self.queues = AdmissionQueues(self.scheduler)
        self.thresholds = PhaseThresholds(resume_max_new=self.ecfg.b_max)
        self._buckets = _resume_buckets(self.ecfg)

        self._ex = get_executables(
            model_cfg, self.ecfg.num_slots, self.ecfg.max_seq,
            self.ecfg.moe_mode)
        self._decode_fn, self._prefill_fn = self._ex.decode, self._ex.prefill
        # resume batch sizes: powers of two up to the M cap (exact-M
        # dispatch — batches round *down* to a warmed size, no padding
        # rows, so the scatter never sees duplicate slot indices)
        self._resume_levels = []
        m = 1
        while m <= min(self.ecfg.resume_batch_max, self.ecfg.num_slots):
            self._resume_levels.append(m)
            m *= 2
        # cold-pack batch sizes (packed cold prefills, DESIGN.md §4);
        # m = 1 falls back to the batch-1 slot executable
        self._cold_levels = []
        m = 2
        while m <= min(self.ecfg.cold_batch_max, self.ecfg.num_slots):
            self._cold_levels.append(m)
            m *= 2
        self._warmed_packs: set = set()
        # chunk autotune table: executable + measured tok/s per warmed
        # prefill chunk shape (filled by _build_slot at warmup)
        self._chunk_fns: Dict[int, Callable] = {}
        self._chunk_tok_s: Dict[int, float] = {}
        self.slots = SlotManager(
            C, g, self._build_slot, preestablish=policy.preestablish)
        self.megasteps: Optional[SlotManager] = None
        if self.ecfg.megastep_max >= self.ecfg.megastep_unit >= 2:
            total = (self.ecfg.megastep_max // self.ecfg.megastep_unit
                     * self.ecfg.megastep_unit)
            self.megasteps = SlotManager(
                total, self.ecfg.megastep_unit, self._build_megastep,
                preestablish=policy.preestablish)
        self._warm_shared()

        # run-state
        self._t0 = time.perf_counter()
        self.trace: List[Dict] = []       # per-cycle telemetry (Fig 2)
        # plan → execute state (DESIGN.md §9)
        self.dispatcher = Dispatcher(self)
        self.journal = PlanJournal(max_records=self.ecfg.journal_max)
        self._cycle = 0
        # reactor state (DESIGN.md §6): the registry of live sessions,
        # the control-clock deadline, and the per-cycle token events
        # drained by step().  run() and the online gateway share these.
        self._sessions: Dict[int, Session] = {}
        self._events: List[TokenEvent] = []
        self._next_ctrl = self.ecfg.control_interval_s
        self._parked: Dict[int, object] = {}   # sid -> parked KV snapshot
        self._paused_seq: Dict[int, int] = {}  # sid -> preemption stamp
        self._preempt_count = 0
        self._prefix_keys: Dict[int, str] = {}  # sid -> cached prefix hash
        self.last_step_did_work = False
        self.event_log: List[TokenEvent] = []  # run(), record_events only
        # device-resident decode state (rebuilt from host mirrors only on
        # membership changes; see DESIGN.md §3)
        B = self.ecfg.num_slots
        self._dev_tokens = jnp.zeros((B,), jnp.int32)
        self._dev_lengths = jnp.zeros((B,), jnp.int32)
        self._dev_mask = jnp.zeros((B,), bool)
        self._dev_ids: List[int] = []
        self._dev_dirty = True
        # telemetry window (sampled-cadence sync)
        self._window_t0: Optional[float] = None
        self._window_steps = 0
        self._window_sessions: List[Session] = []
        # per-step token arrays accumulated within the window so the
        # flush can emit true per-token TokenEvents (megasteps hand back
        # their [K, B] token sequence; holding the device arrays costs
        # nothing — they are outputs the executables produce anyway)
        self._window_toks: List[jax.Array] = []
        # unified telemetry (DESIGN.md §11): one registry is THE stats
        # surface — engine.stats(), gateway.stats() and GET /stats +
        # /metrics all read it, so their key sets cannot drift.  The
        # legacy hotpath_stats dict keeps its call-site syntax via
        # RegistryDict; keys that would collide with gateway counters
        # register under an engine_ prefix.
        self.telemetry = Telemetry(enabled=self.ecfg.telemetry,
                                   spans_max=self.ecfg.spans_max)
        reg = self.telemetry.registry
        self.hotpath_stats = RegistryDict(
            reg,
            {"fused_steps": 0, "megasteps": 0,
             "mega_tokens": 0, "resume_batches": 0,
             "resume_jobs": 0, "capacity_overruns": 0,
             "cold_batches": 0, "cold_jobs": 0,
             "prefill_tiles_streamed": 0,
             "prefill_tiles_skipped": 0,
             "parks": 0, "unparks": 0,
             "preemptions": 0, "preempt_resumes": 0,
             "aborted": 0, "deadline_aborts": 0,
             "kv_deferred": 0},
            rename={"aborted": "engine_aborted",
                    "parks": "engine_parks",
                    "unparks": "engine_unparks"},
            help_prefix="engine hot-path counter: ")
        self._h_ttft = reg.histogram(
            "ttft_s", help="request submission -> first token (s)")
        self._h_tpot = reg.histogram(
            "tpot_s", help="inter-token gap within decode bursts (s)")
        self._h_gap = reg.histogram(
            "dispatch_gap_s",
            help="host gap between consecutive decode dispatches (s)")
        self._h_devwait = reg.histogram(
            "device_wait_s",
            help="block_until_ready wait at decode flush points (s)")
        self._h_host = reg.histogram(
            "cycle_host_s", help="wall time of one dispatched cycle (s)")
        reg.gauge("q_decode", help="decode-queue depth",
                  fn=lambda: float(self.queues.occupancy()[0]))
        reg.gauge("q_prefill", help="prefill-queue depth",
                  fn=lambda: float(self.queues.occupancy()[1]))
        reg.gauge("free_slots", help="unbound KV slots",
                  fn=lambda: float(self.pool.free_slots))
        reg.gauge("slots_in_use", help="bound KV slots",
                  fn=lambda: float(self.pool.slots_in_use))
        reg.gauge("prefix_hits", help="prefix-cache restores",
                  fn=lambda: float(self.pool.stats["prefix_hits"]))
        reg.gauge("kv_pressure", help="1 when a KVExhausted deferral "
                  "happened within the last 50 cycles",
                  fn=lambda: float(self.kv_pressure_recent()))
        if self._paged:
            reg.gauge("free_pages", help="free KV arena pages",
                      fn=lambda: float(self.pool.free_pages))
            reg.gauge("pages_in_use", help="allocated KV arena pages",
                      fn=lambda: float(self.pool.pages_in_use))
            reg.gauge("page_copies", help="copy-on-write page copies",
                      fn=lambda: float(self.pool.stats["page_copies"]))
        # dispatch-gap + per-cycle accounting state
        self._last_dispatch_t: Optional[float] = None
        self._cycle_decode_tokens = 0
        self._cycle_prefill_tokens = 0
        self._cycle_block_s = 0.0
        # fault-domain state (DESIGN.md §10): the installed chaos plan,
        # per-session KVExhausted deferral counts, and the last cycle a
        # deferral happened (the gateway's admission-tightening signal)
        self.faults = None
        self._kv_retries: Dict[int, int] = {}
        self._kv_last_defer_cycle = -(10 ** 9)
        # prefill-side telemetry accumulated at dispatch time (host
        # arithmetic only) and folded into hotpath_stats at the sampled
        # flush cadence
        self._prefill_pending = {"cold_batches": 0, "cold_jobs": 0,
                                 "prefill_tiles_streamed": 0,
                                 "prefill_tiles_skipped": 0}

    # ------------------------------------------------------------------
    # executables & warmup
    # ------------------------------------------------------------------
    def _cache_clone(self):
        """A sacrificial copy of the pool cache for warming donating
        executables (the donated input is consumed by the call)."""
        return jax.tree.map(jnp.copy, self.pool.cache)

    def _bt(self) -> Tuple:
        """Trailing block-table args for paged executables (empty under
        the slab layout, so call sites can splat unconditionally)."""
        if not self._paged:
            return ()
        return (self.pool.block_tables_device(),)

    def _prepare_append(self, slot: int, n: int) -> None:
        """Paged pre-dispatch hook: grow/COW ``slot``'s block table to
        cover the next ``n`` tokens (no-op under the slab layout)."""
        if self._paged:
            self.pool.prepare_append(slot, int(self.pool.lengths[slot]), n)

    def _build_slot(self, level: int):
        """Slot executable for decode-reservation ``level``: the prefill
        chunk is C - level tokens.  Pre-establishing == compiling now;
        the No-Green path lands this cost inside the serving loop."""
        chunk = self.ecfg.cycle_budget - level
        if chunk <= 0:
            return {"chunk": 0, "fn": None}
        if self.policy.preestablish:
            fn = self._prefill_fn
        else:
            _, raw_p, _, _, _ = _raw_fns(self.mcfg, self.ecfg.moe_mode)
            fn = jax.jit(raw_p)          # fresh cache -> real recompile
        self._warm_prefill(fn, chunk)
        if self.policy.preestablish and self.ecfg.autotune_chunks:
            # chunk autotune (DESIGN.md §4): measure each warmed chunk
            # shape's throughput so dispatch can pick the best chunk ≤ a
            # budget instead of assuming the full budget is optimal.
            # No-Green skips this: timing inside the serving path would
            # contaminate the on-demand-construction ablation.
            self._chunk_fns[chunk] = fn
            self._chunk_tok_s[chunk] = chunk / self._time_prefill(fn, chunk)
        return {"chunk": chunk, "fn": fn}

    def _time_prefill(self, fn, chunk: int, reps: int = 2) -> float:
        """Best-of-``reps`` wall time of one warmed chunk call (the new
        cache output is discarded; pool state is untouched)."""
        toks = jnp.zeros((1, chunk), jnp.int32)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            lg, _ = fn(self.params, self.pool.cache, toks,
                       jnp.int32(0), jnp.int32(0), jnp.int32(chunk - 1),
                       *self._bt())
            jax.block_until_ready(lg)
            best = min(best, time.perf_counter() - t0)
        return max(best, 1e-9)

    def _build_megastep(self, level: int):
        """Megastep executable fusing ``level`` decode iterations."""
        if self.policy.preestablish:
            fn = self._ex.megastep(level)
        else:
            # No-Green ablation: a fresh jit so on-demand construction
            # pays real XLA compilation inside the serving path (the
            # shared _EXEC_CACHE executable would already be compiled)
            _, _, _, raw_m, _ = _raw_fns(self.mcfg, self.ecfg.moe_mode)
            fn = jax.jit(functools.partial(raw_m, num_steps=level),
                         donate_argnums=(1,))
        B = self.ecfg.num_slots
        toks, _, _, _ = fn(self.params, self._cache_clone(),
                           jnp.zeros((B,), jnp.int32),
                           jnp.zeros((B,), jnp.int32),
                           jnp.zeros((B,), bool), *self._bt())
        jax.block_until_ready(toks)
        return {"steps": level, "fn": fn}

    def _warm_prefill(self, fn, chunk: int) -> None:
        toks = jnp.zeros((1, chunk), jnp.int32)
        lg, _ = fn(self.params, self.pool.cache, toks,
                   jnp.int32(0), jnp.int32(0), jnp.int32(chunk - 1),
                   *self._bt())
        jax.block_until_ready(lg)

    def _warm_resume(self, m: int, bucket: int) -> None:
        if (m, bucket) in self._warmed_packs:
            return      # resume and cold-pack grids share (M, bucket) shapes
        self._warmed_packs.add((m, bucket))
        lg, _ = self._ex.resume(
            self.params, self._cache_clone(),
            jnp.zeros((m, bucket), jnp.int32),
            jnp.arange(m, dtype=jnp.int32),
            jnp.zeros((m,), jnp.int32),
            jnp.full((m,), bucket - 1, jnp.int32), *self._bt())
        jax.block_until_ready(lg)

    def _warm_shared(self) -> None:
        B = self.ecfg.num_slots
        zeros_b = jnp.zeros((B,), jnp.int32)
        lg, _ = self._decode_fn(self.params, self.pool.cache, zeros_b,
                                zeros_b, *self._bt())
        jax.block_until_ready(lg)
        nt, _, _ = self._ex.fused(self.params, self._cache_clone(), zeros_b,
                                  zeros_b, jnp.zeros((B,), bool),
                                  *self._bt())
        jax.block_until_ready(nt)
        if self.policy.resume_to_decode_queue:
            for m in self._resume_levels:
                for b in self._buckets:
                    self._warm_resume(m, b)
        if self._cold_levels and not self.policy.whole_prefill:
            # packed cold prefills dispatch the same [M, bucket] batched
            # executable as resumes; warm any shapes resume didn't
            for m in self._cold_levels:
                for b in self._buckets:
                    self._warm_resume(m, b)
        if self.policy.whole_prefill:
            self._warm_prefill(self._prefill_fn, self._buckets[-1])
        if not self.policy.chunk_by_slots and not self.policy.whole_prefill:
            self._warm_prefill(self._prefill_fn, self._fixed_chunk())

    def _fixed_chunk(self) -> int:
        g = self.ecfg.granularity
        c = int(self.policy.fixed_chunk_frac * self.ecfg.cycle_budget)
        return max(g, (c // g) * g)

    # ------------------------------------------------------------------
    # prefill work execution
    # ------------------------------------------------------------------
    def _run_prefill_tokens(self, sess: Session, shape_len: int,
                            take: Optional[int] = None,
                            fn: Optional[Callable] = None) -> None:
        """Prefill up to ``take`` real tokens (default: fill the shape)
        of the session's current turn in an executable of token-shape
        ``shape_len`` — shorter work is padded and masked.  The call is
        dispatched asynchronously; the host only blocks on the logits
        when this chunk completes the prefill."""
        self._fault_check([sess.session_id])
        take = min(take if take is not None else shape_len, shape_len,
                   self._aligned_remaining(sess))
        if take <= 0:
            return
        if self.pool.lengths[sess.slot] + take > self.ecfg.max_seq - 1:
            self.hotpath_stats["capacity_overruns"] += 1  # DESIGN.md §3
        turn = sess.current_turn
        toks = turn.prefill_tokens[sess.prefill_done: sess.prefill_done + take]
        pad = shape_len - take
        if pad:
            toks = np.concatenate([toks, np.zeros(pad, np.int32)])
        fn = fn or self._prefill_fn
        self._prepare_append(sess.slot, take)
        logits, new_cache = fn(
            self.params, self.pool.cache,
            jnp.asarray(toks[None], jnp.int32),
            jnp.int32(sess.slot), jnp.int32(self.pool.lengths[sess.slot]),
            jnp.int32(take - 1), *self._bt())
        self._note_prefill_dispatch([self.pool.lengths[sess.slot]], shape_len)
        self._cycle_prefill_tokens += take
        self.pool.cache = new_cache
        self.pool.lengths[sess.slot] += take
        sess.prefill_done += take
        sess.cached_len = int(self.pool.lengths[sess.slot])
        self._maybe_register_prefix(sess)
        if sess.remaining_prefill == 0:
            self._finish_prefill(sess, np.asarray(logits))

    def _note_prefill_dispatch(self, cached_lens, shape_len: int,
                               cold_pack: int = 0) -> None:
        """Prefill-side hot-path telemetry (host arithmetic only): per
        dispatched row, the cache-aware kernel streams KV tiles up to
        the row's post-chunk valid length and skips the rest of the
        padded ``max_seq`` extent — the estimate mirrors the kernel's
        causal+length tile bound at ``prefill_tile`` granularity."""
        bk = self.ecfg.prefill_tile
        total = -(-self.ecfg.max_seq // bk)
        streamed = sum(min(-(-(int(l) + shape_len) // bk), total)
                       for l in cached_lens)
        p = self._prefill_pending
        p["prefill_tiles_streamed"] += streamed
        p["prefill_tiles_skipped"] += len(cached_lens) * total - streamed
        if cold_pack:
            p["cold_batches"] += 1
            p["cold_jobs"] += cold_pack

    def _maybe_register_prefix(self, sess: Session) -> None:
        """Prefix registration at the shared-prompt boundary (cold only)."""
        if (sess.turn_idx == 0 and sess.shared_prefix_len > 0
                and sess.cached_len == sess.shared_prefix_len
                and sess.prefill_done == sess.shared_prefix_len):
            self.pool.register_prefix(
                sess.slot,
                sess.turns[0].prefill_tokens[:sess.shared_prefix_len])

    def _aligned_remaining(self, s: Session) -> int:
        """Remaining prefill, capped at the shared-prefix boundary so the
        prefix snapshot is taken at exactly that length."""
        rem = s.remaining_prefill
        if (s.turn_idx == 0 and s.prefill_done < s.shared_prefix_len
                and s.cached_len < s.shared_prefix_len):
            rem = min(rem, s.shared_prefix_len - s.prefill_done)
        return rem

    def _finish_prefill(self, sess: Session, last_logits: np.ndarray) -> None:
        self._flush_decode()             # decode membership changes below
        self._dev_dirty = True
        now = self._clock()
        sess.last_token = int(last_logits.argmax())
        sess.first_token_s.append(now)
        sess.token_times_s.append(now)
        sess.decoded = 1
        self._h_ttft.observe(now - sess.arrival_s)
        tr = self.telemetry.tracer
        if tr is not None:
            # DECODE span start == first-token timestamp; ``tokens``
            # lets the span reconstruction recover the mean TPOT
            tr.transition(sess.session_id, "DECODE", now,
                          tokens=sess.current_turn.decode_len,
                          turn=sess.turn_idx)
        self._emit(sess, sess.last_token, now, index=0, first=True,
                   turn_end=sess.decoded >= sess.current_turn.decode_len)
        self._after_token(sess, now)

    def _emit(self, sess: Session, token, t: float, index: int,
              first: bool = False, turn_end: bool = False) -> None:
        """Record one emitted token as a reactor event (drained by
        ``step()``).  Must run *before* ``_after_token`` advances
        ``turn_idx`` so the event names the turn that produced it."""
        self._events.append(TokenEvent(
            session_id=sess.session_id, token=int(token), t=t,
            turn_idx=sess.turn_idx, index=index, first=first,
            turn_end=turn_end,
            session_end=turn_end and sess.turn_idx + 1 >= len(sess.turns)))

    # ------------------------------------------------------------------
    # decode stream (device-resident)
    # ------------------------------------------------------------------
    def _sync_device_state(self, active: Sequence[Session]) -> None:
        """Rebuild the device token/length/mask arrays from host mirrors.
        Only happens when decode membership changed (joins, leaves,
        restores) — every such event passes through a flush, so the host
        mirrors are exact at this point."""
        ids = [s.session_id for s in active]
        if not self._dev_dirty and ids == self._dev_ids:
            return
        if self._window_steps:
            self._flush_decode()
        B = self.ecfg.num_slots
        tokens = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for s in active:
            tokens[s.slot] = s.last_token
            mask[s.slot] = True
        self._dev_tokens = jnp.asarray(tokens)
        self._dev_mask = jnp.asarray(mask)
        self._dev_lengths = jnp.asarray(self.pool.lengths)
        self._dev_ids = ids
        self._dev_dirty = False

    def _decode_dispatch(self, active: Sequence[Session],
                         megastep_target: int) -> None:
        """Dispatch one fused decode step — or a K-step megastep when
        the plan asked for one — without blocking on the result.  The
        planner's K target is clamped to the live burst/capacity bounds
        (correctness clamps, not decisions)."""
        ecfg = self.ecfg
        self._fault_check([s.session_id for s in active])
        if (self._window_sessions
                and [s.session_id for s in self._window_sessions]
                != [s.session_id for s in active]):
            self._flush_decode()         # defensive: membership changed
        k_alive = min(s.current_turn.decode_len - s.decoded for s in active)
        k_cap = (ecfg.max_seq - 1
                 - max(int(self.pool.lengths[s.slot]) for s in active))
        if k_cap < 1:
            # a lane is at the usable capacity (max_seq - 1 rows; the
            # last row is the hot-path scratch row — DESIGN.md §3).
            # Proceed like the seed did at max_seq, but count it.
            self.hotpath_stats["capacity_overruns"] += 1
            k_cap = 1
        exe, K = None, 1
        if self.megasteps is not None and megastep_target > 0:
            bound = self.megasteps.bind_down(
                min(megastep_target, k_alive, k_cap))
            if bound is not None:
                exe, K = bound[0]["fn"], bound[1]
        if self._window_steps + K > ecfg.telemetry_sample_steps:
            self._flush_decode()
        try:
            for s in active:
                # paged: grow/COW each active lane's table to cover the
                # K decode writes BEFORE the device dispatch — the block
                # table is fixed for the whole (mega)step
                self._prepare_append(s.slot, K)
        except KVExhausted:
            # decode cannot proceed without its pages: skip this cycle's
            # decode (pages already prepped for earlier lanes stay owned
            # by their slots — consistent, just early) and retry next
            # cycle; past the defer limit the offending session aborts
            self._kv_defer_or_abort(s.session_id)
            return
        for s in active:
            self._kv_retries.pop(s.session_id, None)
        self._sync_device_state(active)
        if self._window_t0 is None:
            self._window_t0 = self._clock()
        # host gap between consecutive decode dispatches (the ROADMAP
        # host-overhead histogram): previous dispatch return -> this
        # dispatch's device submission.  KVExhausted returns above never
        # reach here, so a deferred cycle cannot corrupt the series.
        t_disp = self._clock()
        if self._last_dispatch_t is not None:
            self._h_gap.observe(t_disp - self._last_dispatch_t)
        if exe is not None:
            step_toks, nt, nc, nl = exe(self.params, self.pool.cache,
                                        self._dev_tokens, self._dev_lengths,
                                        self._dev_mask, *self._bt())
            self._window_toks.append(step_toks)      # [K, B] per-step ids
            self.hotpath_stats["megasteps"] += 1
            self.hotpath_stats["mega_tokens"] += K * len(active)
        else:
            nt, nc, nl = self._ex.fused(self.params, self.pool.cache,
                                        self._dev_tokens, self._dev_lengths,
                                        self._dev_mask, *self._bt())
            self._window_toks.append(nt)             # [B] one-step ids
            self.hotpath_stats["fused_steps"] += 1
        self._dev_tokens, self._dev_lengths = nt, nl
        self.pool.cache = nc
        self._last_dispatch_t = self._clock()
        self._cycle_decode_tokens += K * len(active)
        self._window_steps += K
        self._window_sessions = list(active)
        burst_done = False
        for s in active:
            s.decoded += K
            self.pool.lengths[s.slot] += K
            s.cached_len = int(self.pool.lengths[s.slot])
            burst_done |= s.decoded >= s.current_turn.decode_len
        if burst_done:
            self._flush_decode()

    def _flush_decode(self) -> None:
        """Sampled-cadence host sync: block on the decode stream, record
        the aggregate inter-emission gap (TPOT x steps) and assign token
        timestamps interpolated across the window.  Prefill-side
        counters accumulated since the last flush fold into
        ``hotpath_stats`` here (the same sampled cadence)."""
        for k, v in self._prefill_pending.items():
            self.hotpath_stats[k] += v
            self._prefill_pending[k] = 0
        n = self._window_steps
        if n == 0:
            return
        t_wait = self._clock()
        jax.block_until_ready(self._dev_tokens)
        now = self._clock()
        self._h_devwait.observe(now - t_wait)
        self._cycle_block_s += now - t_wait
        t0 = self._window_t0
        if t0 is not None and now > t0:
            self.scheduler.record_decode_step(now - t0, steps=n)
            ts = [t0 + (now - t0) * (i + 1) / n for i in range(n)]
            # one weighted observation per flush, not one per token:
            # the window-mean gap for each of the window's n steps
            # across every session in the window
            self._h_tpot.observe((now - t0) / n,
                                 n * len(self._window_sessions))
        else:
            ts = [now] * n
        toks = np.asarray(self._dev_tokens)
        B = self.ecfg.num_slots
        step_toks = np.concatenate(
            [np.asarray(a).reshape(-1, B) for a in self._window_toks],
            axis=0) if self._window_toks else np.zeros((0, B), np.int32)
        assert step_toks.shape[0] == n, (step_toks.shape, n)
        sessions = self._window_sessions
        self._window_sessions = []
        self._window_steps = 0
        self._window_toks = []
        self._window_t0 = now
        for s in sessions:
            s.last_token = int(toks[s.slot])
            s.token_times_s.extend(ts)
            # every session in the window decoded exactly n tokens; its
            # burst position before the window was (decoded - n)
            base = s.decoded - n
            dlen = s.current_turn.decode_len
            for i in range(n):
                self._emit(s, step_toks[i, s.slot], ts[i], index=base + i,
                           turn_end=base + i + 1 >= dlen)
            self._after_token(s, now)

    def _after_token(self, sess: Session, now: float) -> None:
        turn = sess.current_turn
        if sess.decoded < turn.decode_len:
            sess.state = SessionState.DECODING
            return
        self._dev_dirty = True           # session leaves the decode stream
        tr = self.telemetry.tracer
        if sess.turn_idx + 1 >= len(sess.turns):
            sess.state = SessionState.FINISHED
            self.pool.free(sess.slot)
            if tr is not None:
                tr.slot_free(sess.slot, now)
                tr.transition(sess.session_id, "DONE", now)
            return
        if tr is not None:
            tr.transition(sess.session_id, "TOOL_WAIT", now,
                          turn=sess.turn_idx + 1)
        sess.turn_idx += 1
        sess.prefill_done = 0
        sess.decoded = 0
        if sess.external_tools:
            # online mode: the gateway owns the tool-wait clock — the
            # session parks in TOOL_WAIT until resume_session() re-arms
            # it (satellite: tool latency is no longer an engine-side
            # simulation detail for gateway sessions)
            sess.state = SessionState.TOOL_WAIT
            sess.ready_s = float("inf")
        else:
            sess.state = SessionState.TOOL_CALL
            sess.ready_s = now + sess.turns[sess.turn_idx - 1].tool_latency_s

    # ------------------------------------------------------------------
    # plan execution: admission
    # ------------------------------------------------------------------
    def _slot_bind_span(self, slot: int, sid: int, t: float) -> None:
        tr = self.telemetry.tracer
        if tr is not None:
            tr.slot_bind(slot, sid, t)

    def _slot_free_span(self, slot: int, t: float) -> None:
        tr = self.telemetry.tracer
        if tr is not None:
            tr.slot_free(slot, t)

    def _exec_admission(self, adm: Admission, now: float) -> None:
        s = self._sessions.get(adm.session_id)
        if s is None:
            return
        if s.state == SessionState.WAITING_PREFILL:
            if self.pool.free_slots == 0:
                return  # backpressure: the planner retries next cycle
            try:
                s.slot = self.pool.alloc()
            except KVExhausted:
                self._kv_defer_or_abort(s.session_id)
                return  # admission deferred: retries next cycle
            self._slot_bind_span(s.slot, s.session_id, now)
            # always probe, even when the plan's peek saw a miss: the
            # pool's hit/miss accounting and LRU recency refresh are
            # dispatch-time effects that must happen exactly once —
            # adm.restore_prefix records the planner's expectation
            self._maybe_restore_prefix(s)
        elif s.state == SessionState.TOOL_CALL:
            if adm.unpark and s.slot < 0 and s.session_id in self._parked:
                # parked during TOOL_WAIT (release-under-pressure
                # policy): needs a fresh slot + a lossless restore
                # before its resume prefill may run
                if self.pool.free_slots == 0:
                    return
                try:
                    s.slot = self.pool.alloc()
                except KVExhausted:
                    self._kv_defer_or_abort(s.session_id)
                    return
                self.pool.unpark(s.slot,
                                 self._parked.pop(s.session_id))
                self.hotpath_stats["unparks"] += 1
                self._slot_bind_span(s.slot, s.session_id, now)
            elif s.slot < 0:
                return                   # parked, but the plan diverged
        else:
            return                       # stale plan entry
        self._submit(s, now, adm)

    def _maybe_restore_prefix(self, s: Session) -> None:
        if s.shared_prefix_len <= 0:
            return
        entry = self.pool.lookup(
            s.turns[0].prefill_tokens[:s.shared_prefix_len])
        if entry is not None:
            self.pool.restore_prefix(s.slot, entry)
            s.cached_len = entry.length
            s.prefill_done = entry.length

    def _submit(self, s: Session, now: float, adm: Admission) -> None:
        s.arrival_s = now
        s.request_arrivals.append(now)
        # queue delay: how long the request sat ready (slot/backpressure
        # wait) before admission — the open-loop breakdown metric
        s.queue_delays_s.append(max(0.0, now - s.ready_s)
                                if np.isfinite(s.ready_s) else 0.0)
        s.state = SessionState.PREFILLING
        tr = self.telemetry.tracer
        if tr is not None:
            # span start == request_arrivals entry: the TTFT operand
            tr.transition(s.session_id,
                          "RESUME" if s.turn_idx else "PREFILL", now,
                          turn=s.turn_idx)
        job = Job(session_id=s.session_id, phase=adm.phase,
                  new_len=s.remaining_prefill, arrival_s=now)
        if adm.to_decode_queue:
            self.queues.q_decode.append(job)
        else:
            job.enqueued_cold = adm.phase == Phase.RESUME_PREFILL
            self.queues.q_prefill.append(job)

    # ------------------------------------------------------------------
    # plan execution: preemption (PriorityPlanner)
    # ------------------------------------------------------------------
    def _preempt_prefill(self, sid: int) -> None:
        """Suspend a cold prefill at a chunk boundary: its KV rows stay
        resident on device via the park machinery, the slot is freed,
        and its queue entry is pulled (re-created on unsuspend)."""
        s = self._sessions.get(sid)
        if s is None or s.state != SessionState.PREFILLING or s.slot < 0:
            return
        self._parked[sid] = self.pool.park(s.slot)
        t = self._clock()
        self._slot_free_span(s.slot, t)
        tr = self.telemetry.tracer
        if tr is not None:
            tr.transition(sid, "PAUSED", t)
        s.slot = -1
        s.state = SessionState.PREFILL_PAUSED
        self._preempt_count += 1
        self._paused_seq[sid] = self._preempt_count
        jobs = [j for j in self.queues.q_prefill if j.session_id != sid]
        self.queues.q_prefill.clear()
        self.queues.q_prefill.extend(jobs)
        self.hotpath_stats["preemptions"] += 1

    def _unsuspend_prefill(self, sid: int, now: float) -> None:
        """Resume a suspended cold prefill: unpark its snapshot into a
        fresh slot (bit-identical state) and re-queue its job."""
        s = self._sessions.get(sid)
        if (s is None or s.state != SessionState.PREFILL_PAUSED
                or self.pool.free_slots == 0):
            return
        try:
            s.slot = self.pool.alloc()
        except KVExhausted:
            self._kv_defer_or_abort(s.session_id)
            return

        self.pool.unpark(s.slot, self._parked.pop(sid))
        self._paused_seq.pop(sid, None)
        self._slot_bind_span(s.slot, sid, now)
        tr = self.telemetry.tracer
        if tr is not None:
            # ``resumed`` tells the TTFT reconstruction this PREFILL
            # continues the original request, it does not start one
            tr.transition(sid, "PREFILL", now, turn=s.turn_idx,
                          resumed=True)
        s.state = SessionState.PREFILLING
        self.queues.q_prefill.append(Job(
            session_id=sid, phase=Phase.COLD_PREFILL,
            new_len=s.remaining_prefill, arrival_s=now))
        self.hotpath_stats["preempt_resumes"] += 1

    # ------------------------------------------------------------------
    # plan execution: resume prefills (batched, fused into decode)
    # ------------------------------------------------------------------
    def _exec_resume(self, rp: ResumePlan) -> bool:
        """Pack the planned resume jobs from Q_D into one [M, bucket]
        executable with per-row slots/lengths.  Stale entries scanned on
        the way are dropped; on plan/queue divergence (replay of a
        diverged run) the batch rounds down to a warmed size."""
        qd = self.queues.q_decode
        want = list(rp.session_ids)
        # fault check BEFORE popping queue entries: a SessionFault here
        # propagates with every queue untouched, so abort_session's
        # entry-strip is the only bookkeeping needed
        self._fault_check(want)
        jobs: List[Tuple[Job, Session]] = []
        while qd and len(jobs) < len(want):
            job = qd.popleft()
            s = self._sessions.get(job.session_id)
            if (s is None or s.state != SessionState.PREFILLING
                    or s.remaining_prefill <= 0):
                continue                 # stale entry: dropped
            if job.session_id != want[len(jobs)]:
                qd.appendleft(job)       # diverged from the plan: stop
                break
            jobs.append((job, s))
        if not jobs:
            return False
        if len(jobs) < len(want):
            lvls = [lv for lv in self._resume_levels if lv <= len(jobs)]
            if not lvls:
                for job, _ in reversed(jobs):
                    qd.appendleft(job)
                return False
            m = max(lvls)
            for job, _ in reversed(jobs[m:]):
                qd.appendleft(job)
            jobs = jobs[:m]
        try:
            unfinished = self._dispatch_prefill_batch(jobs, rp.bucket,
                                                      count_overruns=False)
        except KVExhausted as e:
            for job, _ in reversed(jobs):
                qd.appendleft(job)       # whole batch retries next cycle
            self._kv_defer_or_abort(e.session_id)
            return False
        self.hotpath_stats["resume_batches"] += 1
        self.hotpath_stats["resume_jobs"] += len(jobs)
        for job, _ in unfinished:
            qd.append(job)               # continue next cycle
        return True

    def _dispatch_prefill_batch(self, jobs: List[Tuple[Job, Session]],
                                bucket: int, *, count_overruns: bool,
                                cold_pack: int = 0,
                                ) -> List[Tuple[Job, Session]]:
        """Shared [M, bucket] batched-prefill dispatch for resume
        batches and cold packs: assemble per-row tokens/slots/lengths,
        grow block tables, run the batched executable, advance the host
        mirrors, register prefixes and finish completed prefills.
        Returns the (job, session) pairs still mid-prefill — callers
        requeue those per their queue discipline."""
        m = len(jobs)
        takes = []
        toks = np.zeros((m, bucket), np.int32)
        for i, (_, s) in enumerate(jobs):
            take = min(bucket, self._aligned_remaining(s))
            takes.append(take)
            toks[i, :take] = s.current_turn.prefill_tokens[
                s.prefill_done: s.prefill_done + take]
            if (count_overruns and self.pool.lengths[s.slot] + take
                    > self.ecfg.max_seq - 1):
                self.hotpath_stats["capacity_overruns"] += 1
        slots = np.asarray([s.slot for _, s in jobs], np.int32)
        lens = np.asarray([self.pool.lengths[s.slot] for _, s in jobs],
                          np.int32)
        logit_idx = np.asarray([t - 1 for t in takes], np.int32)

        try:
            for i, (_, s) in enumerate(jobs):
                self._prepare_append(s.slot, takes[i])
        except KVExhausted as e:
            # annotate the offending session for the caller's deferral
            # accounting; pages prepped for earlier rows stay owned by
            # their slots (consistent — those appends just retry free)
            e.session_id = s.session_id
            raise
        logits, new_cache = self._ex.resume(
            self.params, self.pool.cache, jnp.asarray(toks),
            jnp.asarray(slots), jnp.asarray(lens), jnp.asarray(logit_idx),
            *self._bt())
        self.pool.cache = new_cache
        self._note_prefill_dispatch(lens, bucket, cold_pack=cold_pack)
        self._cycle_prefill_tokens += sum(takes)

        np_logits: Optional[np.ndarray] = None
        unfinished: List[Tuple[Job, Session]] = []
        for i, (job, s) in enumerate(jobs):
            self.pool.lengths[s.slot] += takes[i]
            s.prefill_done += takes[i]
            s.cached_len = int(self.pool.lengths[s.slot])
            self._maybe_register_prefix(s)
            if s.remaining_prefill == 0:
                if np_logits is None:
                    np_logits = np.asarray(logits)
                self._finish_prefill(s, np_logits[i])
            else:
                unfinished.append((job, s))
        return unfinished

    # ------------------------------------------------------------------
    # plan execution: prefill stream
    # ------------------------------------------------------------------
    def _drop_stale_prefill_heads(self) -> None:
        qp = self.queues.q_prefill
        while qp:
            s = self._sessions.get(qp[0].session_id)
            if s is not None and s.state == SessionState.PREFILLING:
                return
            qp.popleft()                 # drop stale entries at the head

    def _take_prefill_job(self, sid: int) -> Optional[Tuple[Job, Session]]:
        """Remove and return ``sid``'s live Q_P entry (None when absent
        or stale — the planner's view raced a state change)."""
        qp = self.queues.q_prefill
        for i, job in enumerate(qp):
            if job.session_id == sid:
                s = self._sessions.get(sid)
                if s is None or s.state != SessionState.PREFILLING:
                    return None
                del qp[i]
                return job, s
        return None

    def _resolve_cold_fn(self, op: ColdOp, slot_exec) -> Optional[Callable]:
        if op.fn_src == "slot":
            return slot_exec["fn"] if slot_exec else None
        if op.fn_src == "slot_full":
            # opportunistic reclaim: bind the full-budget slot (the
            # No-Green path pays on-demand construction here)
            full_exec, _ = self.slots.bind(self.scheduler.cfg.r_base)
            return full_exec["fn"]
        if op.fn_src == "tuned":
            return self._chunk_fns.get(op.shape)
        return None                      # shared batch-1 prefill

    def _exec_cold_op(self, op: ColdOp, slot_exec) -> bool:
        qp = self.queues.q_prefill
        if op.kind == "pack":
            return self._exec_cold_pack(op)
        got = self._take_prefill_job(op.session_ids[0])
        if got is None:
            return False
        job, s = got
        if s.remaining_prefill == 0:
            # unreachable with our workloads (shared prefix < full prompt);
            # would require a last-token re-run that is unsafe for SSM state
            raise RuntimeError("fully-cached request needs >=1 new token")
        try:
            if op.kind == "whole":
                # llama.cpp-style: run the entire prompt to completion
                while s.state == SessionState.PREFILLING:
                    self._run_prefill_tokens(s, op.shape)
                return True
            fn = self._resolve_cold_fn(op, slot_exec)
            for _ in range(op.reps):
                if s.state != SessionState.PREFILLING:
                    break
                self._run_prefill_tokens(s, op.shape, fn=fn)
        except KVExhausted:
            # the chunk's prepare_append rolled back cleanly: the job
            # returns to the head of Q_P and retries next cycle (work
            # already chunked in stays — lengths only advance on
            # successful dispatch)
            qp.appendleft(job)
            self._kv_defer_or_abort(s.session_id)
            return True
        if s.state == SessionState.PREFILLING:
            qp.appendleft(job)           # unfinished: stays at the head
        return True

    def _exec_cold_pack(self, op: ColdOp) -> bool:
        """Pack the planned M prefills into one [M, bucket] batched
        executable (the same machinery — and warmed shapes — as batched
        resume).  Unfinished jobs return to the queue head in order."""
        qp = self.queues.q_prefill
        self._fault_check(op.session_ids)    # before any queue pop
        jobs: List[Tuple[Job, Session]] = []
        for sid in op.session_ids:
            got = self._take_prefill_job(sid)
            if got is None:
                continue
            if got[1].remaining_prefill == 0:
                # same loud invariant as the head-of-queue path: silently
                # dropping the job would leak the slot and hang the session
                raise RuntimeError("fully-cached request needs >=1 new token")
            jobs.append(got)
        if not jobs:
            return False
        try:
            unfinished = self._dispatch_prefill_batch(
                jobs, op.shape, count_overruns=True, cold_pack=len(jobs))
        except KVExhausted as e:
            for job, _ in reversed(jobs):
                qp.appendleft(job)       # whole pack retries next cycle
            self._kv_defer_or_abort(e.session_id)
            return False
        for job, _ in reversed(unfinished):
            qp.appendleft(job)           # continue next cycle, in order
        return True

    # ------------------------------------------------------------------
    # reactor surface: attach / step / poll-state (DESIGN.md §6)
    # ------------------------------------------------------------------
    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    clock = _clock                       # public alias for online drivers

    def attach(self, session: Session) -> None:
        """Register a session with the reactor.  ``run()`` attaches its
        whole cohort up front; the online gateway attaches live requests
        one at a time between cycles."""
        if session.session_id in self._sessions:
            raise ValueError(
                f"duplicate session_id {session.session_id}")
        self._sessions[session.session_id] = session
        tr = self.telemetry.tracer
        if tr is not None:
            tr.transition(session.session_id, "QUEUED", self._clock())

    def start_online(self) -> None:
        """Arm the reactor for open-ended stepping: apply the run-start
        policy state without resetting the engine clock (the gateway's
        arrival timestamps are engine-clock values)."""
        self._begin()

    def _begin(self) -> None:
        ecfg = self.ecfg
        r = self.planner.static_r_min(ecfg.cycle_budget, ecfg.granularity)
        if r is not None:
            self.scheduler.state.r_min = r
        self._next_ctrl = self._clock() + ecfg.control_interval_s

    _TERMINAL = (SessionState.FINISHED, SessionState.ABORTED)

    def pending(self) -> bool:
        return any(s.state not in self._TERMINAL
                   for s in self._sessions.values())

    def sessions(self) -> List[Session]:
        """All attached sessions (online reporting reads these)."""
        return list(self._sessions.values())

    def detach(self, session_id: int) -> None:
        """Drop a FINISHED session from the registry.  Long-lived online
        drivers must detach completed sessions or every cycle's
        admission scan (and process memory) grows without bound; the
        reactor does this automatically on ``session_end``."""
        s = self._sessions.get(session_id)
        if s is None:
            return
        if s.state not in self._TERMINAL:
            raise ValueError(f"cannot detach live session {session_id} "
                             f"({s.state})")
        del self._sessions[session_id]
        self._prefix_keys.pop(session_id, None)

    def snapshot(self, now: Optional[float] = None) -> EngineView:
        """The immutable ``EngineView`` the planner sees: queues,
        session phases, control state, slot levels, KV pressure.  Built
        fresh each cycle; the only pool interaction is the non-mutating
        ``peek_prefix`` probe (the actual restore happens at dispatch)."""
        if now is None:
            now = self._clock()
        svs = []
        for s in self._sessions.values():
            t = s.current_turn
            hit = 0
            if (s.state == SessionState.WAITING_PREFILL
                    and s.ready_s <= now and s.shared_prefix_len > 0):
                # the hash is cached per session: a backpressured cohort
                # waiting on slots must not re-hash its prompts per cycle
                key = self._prefix_keys.get(s.session_id)
                if key is None:
                    key = prefix_key(
                        s.turns[0].prefill_tokens[:s.shared_prefix_len])
                    self._prefix_keys[s.session_id] = key
                hit = self.pool.peek_prefix_key(key)
            svs.append(SessionView(
                session_id=s.session_id, state=s.state.value, slot=s.slot,
                turn_idx=s.turn_idx, num_turns=len(s.turns),
                cached_len=s.cached_len, prefill_done=s.prefill_done,
                turn_prefill_len=len(t.prefill_tokens) if t else 0,
                decode_len=t.decode_len if t else 0, decoded=s.decoded,
                shared_prefix_len=s.shared_prefix_len, ready_s=s.ready_s,
                slo=s.slo_class, prefix_hit_len=hit,
                paused_seq=self._paused_seq.get(s.session_id, -1),
                deadline_s=s.deadline_s))
        return EngineView(
            now=now, next_ctrl=self._next_ctrl,
            tpot_step_ms=self.scheduler.state.tpot_step_ms,
            r_min=self.scheduler.state.r_min,
            b_prefill=self.scheduler.state.b_prefill,
            cycle_budget=self.ecfg.cycle_budget,
            granularity=self.ecfg.granularity,
            r_base=self.scheduler.cfg.r_base,
            max_seq=self.ecfg.max_seq,
            free_slots=self.pool.free_slots,
            slot_lengths=tuple(int(x) for x in self.pool.lengths),
            sessions=tuple(svs),
            q_decode=tuple(JobView(j.session_id, j.phase, j.new_len)
                           for j in self.queues.q_decode),
            q_prefill=tuple(JobView(j.session_id, j.phase, j.new_len)
                            for j in self.queues.q_prefill),
            buckets=tuple(self._buckets),
            resume_levels=tuple(self._resume_levels),
            cold_levels=tuple(self._cold_levels),
            megastep_levels=(tuple(self.megasteps.levels)
                             if self.megasteps is not None else ()),
            chunk_tok_s=self._chunk_tok_s,
            autotune=self.ecfg.autotune_chunks,
            min_cached_fraction=self.thresholds.min_cached_fraction,
            resume_max_new=self.thresholds.resume_max_new)

    def step(self) -> List[TokenEvent]:
        """One reactor cycle, plan → execute: the planner decides the
        control update, admissions/routing, the slot level, the decode
        dispatch (and megastep K), the resume-batch composition and the
        cold-prefill chunk assignments from an immutable view; the
        ``Dispatcher`` carries the plan out.  Non-blocking apart from
        the sampled-cadence decode flush.  Returns the token events this
        cycle emitted (``last_step_did_work`` tells idle-sleep callers
        whether anything was dispatched)."""
        ecfg = self.ecfg
        now = self._clock()

        # ---- SLO deadline sweep (DESIGN.md §10) -------------------
        # expired sessions are aborted before the planner snapshots, so
        # a plan never routes work to a session past its deadline
        for s in list(self._sessions.values()):
            if s.deadline_s < now and s.state not in self._TERMINAL:
                if self.abort_session(s.session_id, "deadline"):
                    self.hotpath_stats["deadline_aborts"] += 1

        # ---- control update (Algorithm 1) -------------------------
        ctrl = self.planner.plan_control(now, self._next_ctrl)
        if ctrl.flush:
            self._flush_decode()         # fresh TPOT for the controller
            if ctrl.update:
                self.scheduler.update()
            self._next_ctrl = now + ecfg.control_interval_s

        # ---- plan → execute ---------------------------------------
        view = self.snapshot(now)
        plan = self.planner.plan(view)
        # stamp the telemetry/journal correlation id — but only on the
        # -1 sentinel: a ReplayPlanner hands back recorded plans whose
        # original ids must survive so replayed timelines diff cleanly
        plan = dataclasses.replace(
            plan, control=ctrl,
            plan_id=self._cycle if plan.plan_id < 0 else plan.plan_id)
        if plan.decode is None:
            # decode pauses this cycle: the next dispatch gap would
            # span scheduling dead time, not host dispatch overhead
            self._last_dispatch_t = None
        events_before = len(self._events)
        self._cycle_decode_tokens = 0
        self._cycle_prefill_tokens = 0
        self._cycle_block_s = 0.0
        t_host0 = time.perf_counter()
        try:
            outcome = self.dispatcher.execute(plan, now)
        except SessionFault as f:
            # engine-level quarantine: the fault names exactly one
            # session (checks run *before* device dispatch, so no
            # partial cycle state exists); abort it and keep serving
            self.abort_session(f.session_id, f.reason)
            outcome = CycleOutcome(did_work=True)
        host_s = time.perf_counter() - t_host0

        if outcome.did_work:
            self._h_host.observe(host_s)
            tr = self.telemetry.tracer
            if tr is not None:
                tr.cycle(plan.plan_id, _plan_kind(plan), now,
                         self._clock(),
                         planned=_planned_tokens(plan),
                         actual=(self._cycle_decode_tokens
                                 + self._cycle_prefill_tokens),
                         host_ms=round(host_s * 1e3, 4),
                         block_ms=round(self._cycle_block_s * 1e3, 4),
                         q_d=outcome.q_d, q_p=outcome.q_p)

        if len(self.trace) < ecfg.trace_max:
            self.trace.append(dict(
                t=self._clock(), tpot_ms=self.scheduler.state.tpot_step_ms,
                r_min=self.scheduler.state.r_min,
                b_prefill=self.scheduler.state.b_prefill,
                q_d=outcome.q_d, q_p=outcome.q_p,
                q_p_cold=outcome.q_p_cold, q_p_resume=outcome.q_p_resume,
                active=outcome.active))
        self.journal.record(CycleRecord(
            cycle=self._cycle, plan=plan,
            events=len(self._events) - events_before,
            did_work=outcome.did_work))
        self._cycle += 1
        self.last_step_did_work = outcome.did_work
        events, self._events = self._events, []
        return events

    def flush(self) -> None:
        """Host-sync any in-flight decode window (online drivers call
        this at shutdown; ``run()`` calls it before building the
        report)."""
        self._flush_decode()

    def stats(self) -> Dict[str, float]:
        """The unified stats surface: a flat snapshot of the telemetry
        registry.  ``gateway.stats()`` and the HTTP ``/stats`` route
        return exactly this dict (plus nothing), so the three views
        cannot drift."""
        return self.telemetry.registry.snapshot()

    # ---- online session control --------------------------------------
    def resume_session(self, session_id: int) -> None:
        """Re-arm a TOOL_WAIT session for its next turn.  The gateway
        calls this when the (real or simulated) tool completes — the
        tool-wait clock lives in the gateway, not the engine."""
        s = self._sessions[session_id]
        if s.state != SessionState.TOOL_WAIT:
            raise ValueError(
                f"session {session_id} not in TOOL_WAIT ({s.state})")
        s.state = SessionState.TOOL_CALL
        s.ready_s = self._clock()

    def park_session(self, session_id: int) -> None:
        """Release a TOOL_WAIT session's KV slot under pressure: the
        slot's cache rows (attention KV *and* SSM states) are
        snapshotted host-invisibly on device, the slot is freed for a
        waiting session, and the resume path restores the snapshot into
        a fresh slot — lossless, so the resume prefill is bit-identical
        to the held-slot path."""
        s = self._sessions[session_id]
        if s.state != SessionState.TOOL_WAIT:
            raise ValueError(
                f"session {session_id} not in TOOL_WAIT ({s.state})")
        if s.slot < 0:
            return                       # already parked
        self._parked[session_id] = self.pool.park(s.slot)
        self._slot_free_span(s.slot, self._clock())
        s.slot = -1
        self.hotpath_stats["parks"] += 1

    def abort_session(self, session_id: int, reason: str) -> bool:
        """Quarantine one session (DESIGN.md §10): flush any in-flight
        decode window it sits in, strip its queue entries, reclaim its
        slot / parked pages via the existing free/park machinery, mark
        it ABORTED and emit its terminal error event.  Every other
        session's state is untouched — this is the fault-domain
        boundary.  False when the session is unknown or already
        terminal (abort racing completion is benign)."""
        s = self._sessions.get(session_id)
        if s is None or s.state in (SessionState.FINISHED,
                                    SessionState.ABORTED):
            return False
        if any(w.session_id == session_id for w in self._window_sessions):
            # the window holds real decoded tokens — deliver them first
            self._flush_decode()
            if s.state in (SessionState.FINISHED, SessionState.ABORTED):
                return False             # the flush completed the session
        for q in (self.queues.q_decode, self.queues.q_prefill):
            stale = [j for j in q if j.session_id == session_id]
            for j in stale:
                q.remove(j)
        t_now = self._clock()
        if s.slot >= 0:
            self.pool.free(s.slot)
            self._slot_free_span(s.slot, t_now)
            s.slot = -1
        entry = self._parked.pop(session_id, None)
        if entry is not None:
            self.pool.release_entry(entry)   # paged: drop page refs
        self._paused_seq.pop(session_id, None)
        self._kv_retries.pop(session_id, None)
        self._dev_dirty = True           # decode membership changed
        s.state = SessionState.ABORTED
        s.abort_reason = reason
        self.hotpath_stats["aborted"] += 1
        tr = self.telemetry.tracer
        if tr is not None:
            tr.transition(session_id, "ABORTED", t_now, reason=reason)
        self._events.append(TokenEvent(
            session_id=session_id, token=-1, t=t_now,
            turn_idx=s.turn_idx, index=-1, session_end=True,
            error=True, abort_reason=reason))
        return True

    def install_faults(self, plan) -> None:
        """Arm a chaos ``FaultPlan`` (serving/faults.py): step faults
        check before every dispatch, page faults inside the pool's
        allocator."""
        self.faults = plan
        self.pool.fault_hook = plan.pool_hook

    def _fault_check(self, session_ids) -> None:
        """Chaos hook: called before a dispatch touches device state for
        these sessions — a planned step fault raises ``SessionFault``
        here, where aborting leaves no partial cycle state behind."""
        if self.faults is None:
            return
        for sid in session_ids:
            self.faults.check_step(sid)

    def _kv_defer_or_abort(self, session_id: int) -> None:
        """KVExhausted degradation ladder: count the deferral (the op
        was or will be re-queued — transparent to tokens), and once one
        session has deferred past ``kv_defer_limit`` convert it to a
        ``SessionFault`` — aborting that session frees its pages, which
        is what lets everyone else make progress under hard pressure."""
        self.hotpath_stats["kv_deferred"] += 1
        self._kv_last_defer_cycle = self._cycle
        n = self._kv_retries.get(session_id, 0) + 1
        self._kv_retries[session_id] = n
        if n > self.ecfg.kv_defer_limit:
            raise SessionFault(session_id, "kv_exhausted")

    def kv_pressure_recent(self, window: int = 50) -> bool:
        """True when a KVExhausted deferral happened within the last
        ``window`` cycles — the gateway tightens its admission watermark
        on this signal (shed at the door rather than defer inside)."""
        return self._cycle - self._kv_last_defer_cycle <= window

    def slot_pressure(self) -> bool:
        """True when a waiting session is blocked on slot exhaustion —
        the gateway's trigger for the release-under-pressure policy."""
        if self.pool.free_slots > 0:
            return False
        return any(s.state == SessionState.WAITING_PREFILL
                   or (s.state == SessionState.TOOL_CALL and s.slot < 0)
                   for s in self._sessions.values())

    def admission_occupancy(self) -> int:
        """Open-loop load signal for the gateway watermark: queued jobs
        in both admission queues plus sessions still waiting for a KV
        slot."""
        q_d, q_p = self.queues.occupancy()
        waiting = sum(1 for s in self._sessions.values()
                      if s.state == SessionState.WAITING_PREFILL)
        return q_d + q_p + waiting

    # ------------------------------------------------------------------
    # closed-loop batch API (Fig 5) — reimplemented on the reactor
    # ------------------------------------------------------------------
    def run(self, sessions: Sequence[Session],
            thresholds: Optional[SLOThresholds] = None) -> ServingReport:
        self._sessions = {}
        self._prefix_keys.clear()
        for s in sessions:
            self.attach(s)
        self._t0 = time.perf_counter()
        self._last_dispatch_t = None
        tr = self.telemetry.tracer
        if tr is not None:
            # the clock just restarted: spans opened by attach() above
            # carry pre-reset timestamps — reopen the cohort at t=0
            tr.reset()
            for s in sessions:
                tr.transition(s.session_id, "QUEUED", 0.0)
        self._begin()
        ecfg = self.ecfg
        self.event_log = []

        while self.pending():
            if self._clock() > ecfg.max_wall_s:
                break
            events = self.step()
            if ecfg.record_events:
                self.event_log.extend(events)
            if not self.last_step_did_work:
                time.sleep(0.0005)

        self._flush_decode()
        self._events.clear()
        wall = self._clock()
        extra = {
            "rebinds": float(self.slots.stats.rebinds),
            "mean_rebind_us": self.slots.stats.mean_rebind_us,
            "slot_misses": float(self.slots.stats.misses),
            "prefix_hits": float(self.pool.stats["prefix_hits"]),
        }
        extra.update({k: float(v) for k, v in self.hotpath_stats.items()})
        return build_report(self.policy.name, list(sessions), wall,
                            thresholds, extra)
