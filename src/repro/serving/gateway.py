"""Online serving gateway: async streaming sessions over the reactor.

This is the layer that makes the repro an *online* system (DESIGN.md
§6): live requests arrive at any time, stream their tokens back as they
are decoded, wait out their tool calls under the gateway's clock, and
are shed with 429-style rejections when open-loop pressure crosses the
admission watermark.  The gateway owns:

  * the **reactor loop** — a single asyncio task that serialises all
    engine access: it ingests queued submissions/resumes between
    cycles, advances the engine one ``step()`` at a time (in a worker
    thread, so the event loop keeps serving tool timers and HTTP
    clients during device work), and fans the cycle's ``TokenEvent``s
    out to per-session asyncio queues;
  * the **session state machine** — PREFILL → DECODE → TOOL_WAIT →
    RESUME → DONE.  ``turn_end`` events move a session into TOOL_WAIT,
    where the *gateway* (not the engine) runs the tool: either the
    configured ``tool_fn`` or an ``asyncio.sleep`` of the turn's
    simulated latency.  On completion the session re-enters the engine
    via ``reactor.resume`` (RESUME) and decodes its next turn with its
    KV intact;
  * the **KV-slot policy** during TOOL_WAIT — ``hold`` keeps the slot
    (lowest resume latency), ``release`` parks the slot's cache rows
    on device and frees it when another session is blocked on slot
    exhaustion (higher utilisation; the restore is lossless);
  * **admission** — a hysteretic ``WatermarkGate`` over queue + slot
    occupancy; ``reject`` mode sheds immediately (429), ``queue`` mode
    waits briefly for the gate to reopen before shedding.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import enum
import itertools
from typing import AsyncIterator, Awaitable, Callable, Deque, Dict, List, \
    Optional, Tuple, Union

import numpy as np

from repro.core.admission import WatermarkGate
from repro.serving.reactor import EngineReactor, RequestHandle, TokenEvent
from repro.serving.request import Session

# tool_fn(session, completed_turn_idx) -> optional replacement tokens
# for the *next* turn's prefill (a real tool's output); None keeps the
# scripted tokens.
ToolFn = Callable[[Session, int], Awaitable[Optional[np.ndarray]]]


@dataclasses.dataclass
class GatewayConfig:
    high_watermark: int = 8          # occupancy that closes the gate
    low_watermark: int = -1          # reopen level (default high // 2)
    admission: str = "reject"        # reject -> immediate 429 | queue
    max_queue: int = 32              # queue mode: max concurrent waiters
    queue_timeout_s: float = 2.0     # queue mode: wait bound before 429
    tool_policy: str = "hold"        # hold | release (KV slot in TOOL_WAIT)
    idle_sleep_s: float = 0.001      # reactor loop sleep when no work
    step_in_thread: bool = True      # run engine.step off the event loop
    completed_history: int = 10_000  # finished Sessions kept for reports


class GatewayState(enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"
    TOOL_WAIT = "tool_wait"
    RESUME = "resume"
    DONE = "done"


@dataclasses.dataclass
class Rejected:
    """429-style admission result."""
    status: int = 429
    reason: str = "admission watermark exceeded"
    occupancy: int = 0


class LiveSession:
    """Gateway-owned handle for one streaming agent session."""

    def __init__(self, session: Session):
        self.session = session
        self.handle: Optional[RequestHandle] = None
        self.state = GatewayState.PREFILL
        self.queue: "asyncio.Queue[Optional[TokenEvent]]" = asyncio.Queue()
        self.received: List[TokenEvent] = []

    @property
    def session_id(self) -> int:
        return self.session.session_id

    async def events(self) -> AsyncIterator[TokenEvent]:
        """Stream this session's tokens as they are decoded; terminates
        after the final turn's last token."""
        while True:
            ev = await self.queue.get()
            if ev is None:
                return
            self.received.append(ev)
            yield ev


class AgentGateway:
    """Asyncio front for one ``ServingEngine`` (single engine, many
    concurrent streaming clients)."""

    def __init__(self, engine, config: Optional[GatewayConfig] = None,
                 tool_fn: Optional[ToolFn] = None):
        self.engine = engine
        self.reactor = EngineReactor(engine)
        self.cfg = config or GatewayConfig()
        if self.cfg.tool_policy not in ("hold", "release"):
            raise ValueError(f"unknown tool_policy {self.cfg.tool_policy}")
        if self.cfg.admission not in ("reject", "queue"):
            raise ValueError(f"unknown admission mode {self.cfg.admission}")
        self.gate = WatermarkGate(self.cfg.high_watermark,
                                  self.cfg.low_watermark)
        self.tool_fn = tool_fn
        self._live: Dict[int, LiveSession] = {}
        # engine ops staged by submit()/tool tasks, drained by the
        # reactor loop between cycles — the engine is only ever touched
        # from the loop, so no locking is needed
        self._ops: Deque[Tuple[str, LiveSession]] = collections.deque()
        self._ids = itertools.count()
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._waiters = 0
        self._tool_tasks: set = set()
        # finished sessions, retained (bounded) for open-loop reporting
        # — the engine/reactor detach them at session_end
        self.completed_sessions: Deque[Session] = collections.deque(
            maxlen=self.cfg.completed_history)
        self.counters = {"submitted": 0, "rejected": 0, "completed": 0,
                         "parked": 0, "tool_calls": 0, "tool_errors": 0}

    # ---- lifecycle ----------------------------------------------------
    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("gateway already started")
        self._running = True
        self._task = asyncio.create_task(self._loop())

    async def stop(self, timeout_s: Optional[float] = None) -> None:
        """Stop accepting new work and drain in-flight sessions; cancel
        the loop if the drain exceeds ``timeout_s``."""
        self._running = False
        if self._task is None:
            return
        try:
            await asyncio.wait_for(asyncio.shield(self._task), timeout_s)
        except asyncio.TimeoutError:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
        self._task = None

    # ---- admission ----------------------------------------------------
    def occupancy(self) -> int:
        return self.engine.admission_occupancy() + len(self._ops)

    async def submit(self, session: Session,
                     ) -> Union[LiveSession, Rejected]:
        """Admit a live agent session — or shed it at the watermark.
        The returned ``LiveSession`` streams tokens via ``events()``."""
        occ = self.occupancy()
        if not self.gate.check(occ) and self.cfg.admission == "queue":
            occ = await self._wait_for_gate(occ)
        if not self.gate.offer(occ):
            self.counters["rejected"] += 1
            return Rejected(occupancy=occ)
        session.session_id = next(self._ids)
        session.external_tools = True    # gateway owns the tool clock
        live = LiveSession(session)
        self._live[session.session_id] = live
        self._ops.append(("submit", live))
        self.counters["submitted"] += 1
        return live

    async def _wait_for_gate(self, occ: int) -> int:
        """Queue-mode admission: wait (bounded) for the gate to reopen
        instead of shedding immediately."""
        if self._waiters >= self.cfg.max_queue:
            return occ                   # queue full -> let offer() shed
        self._waiters += 1
        try:
            deadline = (asyncio.get_running_loop().time()
                        + self.cfg.queue_timeout_s)
            while not self.gate.check(occ := self.occupancy()):
                if asyncio.get_running_loop().time() >= deadline:
                    break
                await asyncio.sleep(self.cfg.idle_sleep_s * 5)
        finally:
            self._waiters -= 1
        return occ

    # ---- the reactor loop ---------------------------------------------
    async def _loop(self) -> None:
        cfg = self.cfg
        while self._running or self._ops or self.reactor.pending():
            while self._ops:
                op, live = self._ops.popleft()
                if op == "submit":
                    live.handle = self.reactor.submit(live.session)
                else:                    # "resume"
                    self.reactor.resume(live.handle)
            self._park_under_pressure()
            if cfg.step_in_thread:
                events = await asyncio.to_thread(self.reactor.step)
            else:
                events = self.reactor.step()
                await asyncio.sleep(0)   # let clients/timers breathe
            for ev in events:
                self._route(ev)
            if not events and not self.reactor.did_work and not self._ops:
                await asyncio.sleep(cfg.idle_sleep_s)
        self.engine.flush()

    def _route(self, ev: TokenEvent) -> None:
        live = self._live.get(ev.session_id)
        if live is None:
            return
        live.queue.put_nowait(ev)
        if ev.first:
            live.state = GatewayState.DECODE
        if ev.session_end:
            live.state = GatewayState.DONE
            live.queue.put_nowait(None)  # stream terminator
            self.counters["completed"] += 1
            self.completed_sessions.append(live.session)
            del self._live[ev.session_id]
        elif ev.turn_end:
            live.state = GatewayState.TOOL_WAIT
            task = asyncio.get_running_loop().create_task(
                self._tool_wait(live, ev.turn_idx))
            self._tool_tasks.add(task)
            task.add_done_callback(self._tool_tasks.discard)

    def _park_under_pressure(self) -> None:
        """release policy, checked every loop iteration (not just at
        TOOL_WAIT entry): whenever a waiting session is blocked on slot
        exhaustion, park TOOL_WAIT sessions that still hold a slot
        until the pressure clears."""
        if self.cfg.tool_policy != "release":
            return
        for live in list(self._live.values()):
            if not self.engine.slot_pressure():
                return
            if (live.state == GatewayState.TOOL_WAIT
                    and live.session.slot >= 0):
                self.engine.park_session(live.session_id)
                self.counters["parked"] += 1

    async def _tool_wait(self, live: LiveSession, turn_idx: int) -> None:
        """The tool half of an agent turn, on the gateway's clock.

        A tool_fn failure must not wedge the session in TOOL_WAIT (the
        client's stream would hang forever): the error is counted and
        the session resumes with its scripted next-turn tokens."""
        sess = live.session
        self.counters["tool_calls"] += 1
        try:
            if self.tool_fn is not None:
                next_tokens = await self.tool_fn(sess, turn_idx)
                if next_tokens is not None:
                    # a real tool's output replaces the next turn's
                    # scripted prefill (safe: that prefill hasn't started)
                    sess.turns[turn_idx + 1].prefill_tokens = np.asarray(
                        next_tokens, np.int32)
            else:
                await asyncio.sleep(sess.turns[turn_idx].tool_latency_s)
        except asyncio.CancelledError:
            raise
        except Exception:
            self.counters["tool_errors"] += 1
        live.state = GatewayState.RESUME
        self._ops.append(("resume", live))

    # ---- observability -------------------------------------------------
    def stats(self) -> Dict[str, float]:
        q_d, q_p = self.engine.queues.occupancy()
        out = {
            **{k: float(v) for k, v in self.counters.items()},
            "gate_admitted": float(self.gate.admitted),
            "gate_rejected": float(self.gate.rejected),
            "gate_shedding": float(self.gate.shedding),
            "occupancy": float(self.occupancy()),
            "q_decode": float(q_d),
            "q_prefill": float(q_p),
            "free_slots": float(self.engine.pool.free_slots),
            "live_sessions": float(len(self._live)),
            "engine_parks": float(self.engine.hotpath_stats["parks"]),
            "engine_unparks": float(self.engine.hotpath_stats["unparks"]),
        }
        pool = self.engine.pool
        if hasattr(pool, "free_pages"):   # paged layout (DESIGN.md §8)
            out["free_pages"] = float(pool.free_pages)
            out["prefix_hits"] = float(pool.stats["prefix_hits"])
            out["page_copies"] = float(pool.stats["page_copies"])
        return out


# ---------------------------------------------------------------------------
# open-loop driver (benchmarks, tests, --serve-smoke)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpenLoopRun:
    completed: List[Session]
    rejected: List[Session]
    events: List[Tuple[float, TokenEvent]]   # (driver wall time, event)
    wall_s: float

    def interleaved(self) -> bool:
        """True when token events from different sessions interleave —
        the observable signature of concurrent streaming."""
        switches = sum(1 for a, b in zip(self.events, self.events[1:])
                       if a[1].session_id != b[1].session_id)
        return switches > len({e.session_id for _, e in self.events})


async def drive_open_loop(gateway: AgentGateway, sessions: List[Session],
                          arrivals, *, time_scale: float = 1.0,
                          ) -> OpenLoopRun:
    """Submit ``sessions`` at their open-loop ``arrivals`` offsets (wall
    clock, scaled by ``time_scale``) and consume every stream to
    completion.  One asyncio task per agent — the client side of the
    paper's overlapping multi-agent arrival pattern."""
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    run = OpenLoopRun(completed=[], rejected=[], events=[], wall_s=0.0)

    async def one(sess: Session, at: float) -> None:
        delay = at * time_scale - (loop.time() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        res = await gateway.submit(sess)
        if isinstance(res, Rejected):
            run.rejected.append(sess)
            return
        async for ev in res.events():
            run.events.append((loop.time() - t0, ev))
        run.completed.append(sess)

    await asyncio.gather(*(one(s, float(a))
                           for s, a in zip(sessions, arrivals)))
    run.wall_s = loop.time() - t0
    return run
