"""Online serving gateway: async streaming sessions over the reactor.

This is the layer that makes the repro an *online* system (DESIGN.md
§6): live requests arrive at any time, stream their tokens back as they
are decoded, wait out their tool calls under the gateway's clock, and
are shed with 429-style rejections when open-loop pressure crosses the
admission watermark.  The gateway owns:

  * the **reactor loop** — a single asyncio task that serialises all
    engine access: it ingests queued submissions/resumes between
    cycles, advances the engine one ``step()`` at a time (in a worker
    thread, so the event loop keeps serving tool timers and HTTP
    clients during device work), and fans the cycle's ``TokenEvent``s
    out to per-session asyncio queues;
  * the **session state machine** — PREFILL → DECODE → TOOL_WAIT →
    RESUME → DONE.  ``turn_end`` events move a session into TOOL_WAIT,
    where the *gateway* (not the engine) runs the tool: either the
    configured ``tool_fn`` or an ``asyncio.sleep`` of the turn's
    simulated latency.  On completion the session re-enters the engine
    via ``reactor.resume`` (RESUME) and decodes its next turn with its
    KV intact;
  * the **KV-slot policy** during TOOL_WAIT — ``hold`` keeps the slot
    (lowest resume latency), ``release`` parks the slot's cache rows
    on device and frees it when another session is blocked on slot
    exhaustion (higher utilisation; the restore is lossless);
  * **admission** — a hysteretic ``WatermarkGate`` over queue + slot
    occupancy; ``reject`` mode sheds immediately (429), ``queue`` mode
    waits briefly for the gate to reopen before shedding;
  * the **fault domain** (DESIGN.md §10) — per-session isolation: a
    tool failure retries with timeout + exponential backoff and on
    exhaustion either finishes the turn with scripted tokens or aborts
    the session; an engine-side fault quarantines exactly the offending
    session (``abort_session``) and its stream terminates with an error
    event; client disconnects (``LiveSession.cancel()``) reclaim the
    slot/pages promptly; KV-pressure deferrals tighten the admission
    gate; and a crashed reactor loop fails every live stream loudly
    instead of leaving consumers awaiting forever.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import enum
import itertools
from typing import AsyncIterator, Awaitable, Callable, Deque, Dict, List, \
    Optional, Tuple, Union

import numpy as np

from repro.core.admission import WatermarkGate
from repro.serving.reactor import EngineReactor, RequestHandle, TokenEvent
from repro.serving.request import Session, SessionState
from repro.serving.telemetry import RegistryDict

# tool_fn(session, completed_turn_idx) -> optional replacement tokens
# for the *next* turn's prefill (a real tool's output); None keeps the
# scripted tokens.
ToolFn = Callable[[Session, int], Awaitable[Optional[np.ndarray]]]


@dataclasses.dataclass
class GatewayConfig:
    high_watermark: int = 8          # occupancy that closes the gate
    low_watermark: int = -1          # reopen level (default high // 2)
    admission: str = "reject"        # reject -> immediate 429 | queue
    max_queue: int = 32              # queue mode: max concurrent waiters
    queue_timeout_s: float = 2.0     # queue mode: wait bound before 429
    tool_policy: str = "hold"        # hold | release (KV slot in TOOL_WAIT)
    idle_sleep_s: float = 0.001      # reactor loop sleep when no work
    step_in_thread: bool = True      # run engine.step off the event loop
    completed_history: int = 10_000  # finished Sessions kept for reports
    # --- tool-call resilience (DESIGN.md §10) -------------------------
    tool_timeout_s: float = 30.0     # per-attempt tool call bound
    tool_retries: int = 2            # retries after the first attempt
    tool_backoff_base_s: float = 0.05   # backoff = base * 2^attempt ...
    tool_backoff_max_s: float = 2.0     # ... capped here ...
    tool_backoff_jitter: float = 0.25   # ... +- this fraction (seeded rng)
    tool_failure_policy: str = "finish_turn"  # on retry exhaustion:
    #   finish_turn -> resume with the scripted next-turn tokens
    #   abort       -> abort the session (terminal error event)
    seed: int = 0                    # backoff-jitter rng seed
    # --- deadlines & degradation --------------------------------------
    default_deadline_s: float = float("inf")  # relative SLO deadline
    #                                  applied at submit when the session
    #                                  has none (inf = no deadline)
    kv_pressure_tighten: int = -1    # watermark tightening while the
    #                                  engine reports KVExhausted
    #                                  deferrals (-1 = auto: high // 2)
    kv_pressure_window: int = 50     # engine cycles a deferral stays hot
    max_engine_errors: int = 8       # consecutive failed loop iterations
    #                                  before the gateway fails all live
    #                                  sessions and stops (never hangs)


class GatewayState(enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"
    TOOL_WAIT = "tool_wait"
    RESUME = "resume"
    DONE = "done"
    FAILED = "failed"                # aborted: fault/deadline/disconnect


@dataclasses.dataclass
class Rejected:
    """429-style admission result."""
    status: int = 429
    reason: str = "admission watermark exceeded"
    occupancy: int = 0


class LiveSession:
    """Gateway-owned handle for one streaming agent session."""

    def __init__(self, session: Session, gateway: "AgentGateway"):
        self.session = session
        self._gw = gateway
        self.handle: Optional[RequestHandle] = None
        self.state = GatewayState.PREFILL
        self.queue: "asyncio.Queue[Optional[TokenEvent]]" = asyncio.Queue()
        self.received: List[TokenEvent] = []
        self.cancelled = False
        self.tool_task: Optional[asyncio.Task] = None

    @property
    def session_id(self) -> int:
        return self.session.session_id

    def cancel(self, reason: str = "disconnected") -> None:
        """Client-side abort (disconnect): stage an abort op for the
        reactor loop — the engine reclaims the slot/pages promptly and
        the stream terminates with an error event.  Idempotent; a no-op
        once the session is terminal."""
        if self.cancelled or self.state in (GatewayState.DONE,
                                            GatewayState.FAILED):
            return
        self.cancelled = True
        self._gw.counters["cancelled"] += 1
        self._gw._ops.append(("abort", self, reason))

    async def events(self) -> AsyncIterator[TokenEvent]:
        """Stream this session's tokens as they are decoded; terminates
        after the final turn's last token — or after a terminal *error*
        event (``ev.error``) when the session was aborted (fault,
        deadline, disconnect): consumers never await forever."""
        while True:
            ev = await self.queue.get()
            if ev is None:
                return
            self.received.append(ev)
            yield ev


class AgentGateway:
    """Asyncio front for one ``ServingEngine`` (single engine, many
    concurrent streaming clients)."""

    def __init__(self, engine, config: Optional[GatewayConfig] = None,
                 tool_fn: Optional[ToolFn] = None, faults=None):
        self.engine = engine
        self.reactor = EngineReactor(engine)
        self.cfg = config or GatewayConfig()
        if self.cfg.tool_policy not in ("hold", "release"):
            raise ValueError(f"unknown tool_policy {self.cfg.tool_policy}")
        if self.cfg.admission not in ("reject", "queue"):
            raise ValueError(f"unknown admission mode {self.cfg.admission}")
        if self.cfg.tool_failure_policy not in ("finish_turn", "abort"):
            raise ValueError(
                f"unknown tool_failure_policy {self.cfg.tool_failure_policy}")
        self.gate = WatermarkGate(self.cfg.high_watermark,
                                  self.cfg.low_watermark)
        self.tool_fn = tool_fn
        # chaos plan (serving/faults.py): engine-side hooks installed
        # here; the gateway consults the plan inside tool calls
        self.faults = faults
        if faults is not None:
            engine.install_faults(faults)
        self._live: Dict[int, LiveSession] = {}
        # engine ops staged by submit()/tool tasks/cancel, drained by
        # the reactor loop between cycles — the engine is only ever
        # touched from the loop, so no locking is needed
        self._ops: Deque[Tuple[str, LiveSession, Optional[str]]] = \
            collections.deque()
        self._ids = itertools.count()
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._waiters = 0
        self._tool_tasks: set = set()
        self._rng = np.random.default_rng(self.cfg.seed)  # backoff jitter
        # finished sessions, retained (bounded) for open-loop reporting
        # — the engine/reactor detach them at session_end
        self.completed_sessions: Deque[Session] = collections.deque(
            maxlen=self.cfg.completed_history)
        # aborted sessions (fault/deadline/disconnect), same retention
        self.failed_sessions: Deque[Session] = collections.deque(
            maxlen=self.cfg.completed_history)
        # gateway metrics register into the ENGINE's registry
        # (DESIGN.md §11): engine.stats(), gateway.stats() and the HTTP
        # /stats + /metrics surfaces are all views of one object
        reg = engine.telemetry.registry
        self.counters = RegistryDict(
            reg,
            {"submitted": 0, "rejected": 0, "completed": 0,
             "parked": 0, "tool_calls": 0, "tool_errors": 0,
             "aborted": 0, "cancelled": 0, "tool_retries": 0,
             "tool_timeouts": 0, "engine_errors": 0},
            help_prefix="gateway counter: ")
        reg.gauge("gate_admitted", help="watermark-gate admissions",
                  fn=lambda: float(self.gate.admitted))
        reg.gauge("gate_rejected", help="watermark-gate sheds",
                  fn=lambda: float(self.gate.rejected))
        reg.gauge("gate_shedding", help="1 while the gate is closed",
                  fn=lambda: float(self.gate.shedding))
        reg.gauge("gate_pressure", help="KV-pressure watermark tighten",
                  fn=lambda: float(self.gate.pressure))
        reg.gauge("occupancy", help="admission occupancy (queues + "
                  "waiting sessions + staged ops)",
                  fn=lambda: float(self.occupancy()))
        reg.gauge("live_sessions", help="streaming sessions in flight",
                  fn=lambda: float(len(self._live)))
        reg.gauge("failed_sessions", help="aborted sessions retained "
                  "for reporting",
                  fn=lambda: float(len(self.failed_sessions)))

    # ---- lifecycle ----------------------------------------------------
    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("gateway already started")
        self._running = True
        self._task = asyncio.create_task(self._loop())

    async def stop(self, timeout_s: Optional[float] = None) -> None:
        """Stop accepting new work and drain in-flight sessions; cancel
        the loop if the drain exceeds ``timeout_s``.  A timed-out drain
        pushes a terminal error event to every live session's queue so
        ``events()`` consumers unblock instead of hanging forever."""
        self._running = False
        if self._task is None:
            return
        try:
            await asyncio.wait_for(asyncio.shield(self._task), timeout_s)
        except asyncio.TimeoutError:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._fail_all_live("gateway_stopped")
        self._task = None

    def _fail_all_live(self, reason: str) -> None:
        """Terminate every live stream with an error event (and cancel
        outstanding tool tasks) — the no-consumer-awaits-forever
        backstop for loop death and drain timeouts."""
        for task in list(self._tool_tasks):
            task.cancel()
        for sid, live in list(self._live.items()):
            live.state = GatewayState.FAILED
            live.session.abort_reason = live.session.abort_reason or reason
            live.queue.put_nowait(TokenEvent(
                session_id=sid, token=-1, t=self.engine.clock(),
                turn_idx=live.session.turn_idx, index=-1,
                session_end=True, error=True, abort_reason=reason))
            live.queue.put_nowait(None)
            self.counters["aborted"] += 1
            self.failed_sessions.append(live.session)
            del self._live[sid]

    # ---- admission ----------------------------------------------------
    def occupancy(self) -> int:
        return self.engine.admission_occupancy() + len(self._ops)

    def _kv_pressure_gate(self) -> None:
        """Tighten the admission watermark while the engine reports
        KVExhausted deferrals — shed new load at the door instead of
        deferring it inside (DESIGN.md §10 degradation ladder)."""
        amount = self.cfg.kv_pressure_tighten
        if amount < 0:
            amount = self.cfg.high_watermark // 2
        hot = self.engine.kv_pressure_recent(self.cfg.kv_pressure_window)
        self.gate.set_pressure(amount if hot else 0)

    async def submit(self, session: Session,
                     deadline_s: Optional[float] = None,
                     ) -> Union[LiveSession, Rejected]:
        """Admit a live agent session — or shed it at the watermark.
        The returned ``LiveSession`` streams tokens via ``events()``.
        ``deadline_s`` (relative seconds, overrides the config default)
        arms an engine-enforced SLO deadline: past it the session is
        aborted and its stream ends with an error event."""
        self._kv_pressure_gate()
        occ = self.occupancy()
        if not self.gate.check(occ) and self.cfg.admission == "queue":
            occ = await self._wait_for_gate(occ)
        if not self.gate.offer(occ):
            self.counters["rejected"] += 1
            return Rejected(occupancy=occ)
        session.session_id = next(self._ids)
        session.external_tools = True    # gateway owns the tool clock
        rel = (deadline_s if deadline_s is not None
               else self.cfg.default_deadline_s)
        if np.isfinite(rel):
            session.deadline_s = self.engine.clock() + float(rel)
        live = LiveSession(session, self)
        self._live[session.session_id] = live
        self._ops.append(("submit", live, None))
        self.counters["submitted"] += 1
        return live

    async def _wait_for_gate(self, occ: int) -> int:
        """Queue-mode admission: wait (bounded) for the gate to reopen
        instead of shedding immediately."""
        if self._waiters >= self.cfg.max_queue:
            return occ                   # queue full -> let offer() shed
        self._waiters += 1
        try:
            deadline = (asyncio.get_running_loop().time()
                        + self.cfg.queue_timeout_s)
            while not self.gate.check(occ := self.occupancy()):
                if asyncio.get_running_loop().time() >= deadline:
                    break
                await asyncio.sleep(self.cfg.idle_sleep_s * 5)
        finally:
            self._waiters -= 1
        return occ

    # ---- the reactor loop ---------------------------------------------
    async def _loop(self) -> None:
        """The serialised engine loop, fault-isolated (DESIGN.md §10):
        per-session faults are handled inside ``engine.step`` (quarantine
        via ``abort_session``); anything that still escapes an iteration
        is counted and retried — after ``max_engine_errors`` consecutive
        failures the gateway fails every live stream loudly and exits
        rather than leaving consumers blocked on silent streams."""
        cfg = self.cfg
        errors_in_row = 0
        while self._running or self._ops or self.reactor.pending():
            try:
                self._drain_ops()
                self._park_under_pressure()
                if cfg.step_in_thread:
                    events = await asyncio.to_thread(self.reactor.step)
                else:
                    events = self.reactor.step()
                    await asyncio.sleep(0)   # let clients/timers breathe
                for ev in events:
                    self._route(ev)
                errors_in_row = 0
            except asyncio.CancelledError:
                raise
            except Exception:
                self.counters["engine_errors"] += 1
                errors_in_row += 1
                if errors_in_row >= cfg.max_engine_errors:
                    self._fail_all_live("engine_error")
                    return
                await asyncio.sleep(cfg.idle_sleep_s)
                continue
            if not events and not self.reactor.did_work and not self._ops:
                await asyncio.sleep(cfg.idle_sleep_s)
        self.engine.flush()

    def _drain_ops(self) -> None:
        """Apply staged submit/resume/abort ops to the engine, in FIFO
        order (a session's abort can therefore never precede its own
        submit).  Ops for already-terminal sessions are dropped — abort
        racing completion, resume racing abort — so a stale op can never
        corrupt another session's engine state."""
        while self._ops:
            op, live, arg = self._ops.popleft()
            if op == "submit":
                live.handle = self.reactor.submit(live.session)
                continue
            state = live.session.state
            if state in (SessionState.FINISHED, SessionState.ABORTED):
                continue                 # terminal: drop the stale op
            if op == "resume":
                if not live.cancelled:
                    self.reactor.resume(live.handle)
            else:                        # "abort" (cancel / tool failure)
                if live.tool_task is not None:
                    live.tool_task.cancel()
                self.reactor.abort(live.handle, arg or "aborted")

    def _route(self, ev: TokenEvent) -> None:
        live = self._live.get(ev.session_id)
        if live is None:
            return
        if ev.error:
            # terminal error event (abort_session): fail exactly this
            # stream — deliver the event so the consumer sees the abort
            # reason, then terminate the stream
            live.state = GatewayState.FAILED
            if live.tool_task is not None:
                live.tool_task.cancel()  # e.g. a still-hanging tool
            live.queue.put_nowait(ev)
            live.queue.put_nowait(None)
            self.counters["aborted"] += 1
            self.failed_sessions.append(live.session)
            del self._live[ev.session_id]
            return
        live.queue.put_nowait(ev)
        if ev.first:
            live.state = GatewayState.DECODE
        if ev.session_end:
            live.state = GatewayState.DONE
            live.queue.put_nowait(None)  # stream terminator
            self.counters["completed"] += 1
            self.completed_sessions.append(live.session)
            del self._live[ev.session_id]
        elif ev.turn_end:
            live.state = GatewayState.TOOL_WAIT
            task = asyncio.get_running_loop().create_task(
                self._tool_wait(live, ev.turn_idx))
            live.tool_task = task
            self._tool_tasks.add(task)
            task.add_done_callback(self._tool_tasks.discard)

    def _park_under_pressure(self) -> None:
        """release policy, checked every loop iteration (not just at
        TOOL_WAIT entry): whenever a waiting session is blocked on slot
        exhaustion, park TOOL_WAIT sessions that still hold a slot
        until the pressure clears."""
        if self.cfg.tool_policy != "release":
            return
        for live in list(self._live.values()):
            if not self.engine.slot_pressure():
                return
            if (live.state == GatewayState.TOOL_WAIT
                    and live.session.slot >= 0):
                self.engine.park_session(live.session_id)
                self.counters["parked"] += 1

    def _backoff_s(self, attempt: int) -> float:
        """Exponential backoff with seeded jitter: base * 2^attempt,
        capped, +- jitter fraction.  All on the gateway clock — engine
        determinism is untouched."""
        cfg = self.cfg
        base = min(cfg.tool_backoff_base_s * (2 ** attempt),
                   cfg.tool_backoff_max_s)
        jitter = 1.0 + cfg.tool_backoff_jitter * float(
            self._rng.uniform(-1.0, 1.0))
        return max(0.0, base * jitter)

    async def _call_tool(self, sess: Session, turn_idx: int,
                         attempt: int) -> Optional[np.ndarray]:
        """One tool-call attempt — the chaos plan may turn it into an
        injected error or a hang (which the per-attempt timeout cuts)."""
        if self.faults is not None:
            from repro.serving.faults import InjectedFault
            sp = self.faults.tool_fault(sess.session_id, turn_idx, attempt)
            if sp is not None:
                if sp.kind == "tool_hang":
                    await asyncio.sleep(sp.hang_s)
                raise InjectedFault(
                    f"injected tool_error (session {sess.session_id} "
                    f"turn {turn_idx} attempt {attempt})")
        if self.tool_fn is not None:
            return await self.tool_fn(sess, turn_idx)
        await asyncio.sleep(sess.turns[turn_idx].tool_latency_s)
        return None

    async def _run_tool(self, live: LiveSession, turn_idx: int) -> bool:
        """Tool-call resilience (DESIGN.md §10): per-attempt timeout,
        bounded retries with exponential backoff + jitter.  Returns
        whether any attempt succeeded."""
        cfg, sess = self.cfg, live.session
        tracer = self.engine.telemetry.tracer
        attempts = 1 + max(0, cfg.tool_retries)
        for attempt in range(attempts):
            t_att = self.engine.clock()
            outcome = "error"
            try:
                next_tokens = await asyncio.wait_for(
                    self._call_tool(sess, turn_idx, attempt),
                    timeout=cfg.tool_timeout_s)
                if next_tokens is not None:
                    # a real tool's output replaces the next turn's
                    # scripted prefill (safe: it hasn't started)
                    sess.turns[turn_idx + 1].prefill_tokens = np.asarray(
                        next_tokens, np.int32)
                outcome = "ok"
                return True
            except asyncio.CancelledError:
                raise
            except asyncio.TimeoutError:
                outcome = "timeout"
                self.counters["tool_timeouts"] += 1
            except Exception:
                pass
            finally:
                if tracer is not None:
                    # per-attempt child span under the session's open
                    # TOOL_WAIT span, annotated with retry/timeout fate
                    tracer.child(sess.session_id, "tool_attempt",
                                 t_att, self.engine.clock(),
                                 turn=turn_idx, attempt=attempt,
                                 outcome=outcome)
            if attempt + 1 < attempts:
                self.counters["tool_retries"] += 1
                await asyncio.sleep(self._backoff_s(attempt))
        self.counters["tool_errors"] += 1    # one per exhausted call
        return False

    async def _tool_wait(self, live: LiveSession, turn_idx: int) -> None:
        """The tool half of an agent turn, on the gateway's clock.

        A tool failure must not wedge the session in TOOL_WAIT (the
        client's stream would hang forever).  After retries are
        exhausted the configured policy decides: ``finish_turn`` resumes
        with the scripted next-turn tokens (degraded but complete);
        ``abort`` terminates the session with an error event."""
        sess = live.session
        self.counters["tool_calls"] += 1
        try:
            ok = await self._run_tool(live, turn_idx)
        except asyncio.CancelledError:
            raise
        live.tool_task = None
        if not ok and self.cfg.tool_failure_policy == "abort":
            self._ops.append(("abort", live, "tool_failed"))
            return
        live.state = GatewayState.RESUME
        self._ops.append(("resume", live, None))

    # ---- observability -------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """One snapshot of the unified registry — identical (by
        construction, not convention) to ``engine.stats()`` and to what
        ``GET /stats`` / ``GET /metrics`` serve.  The PR-6 drift where
        fault counters existed in some views but not others cannot
        recur: there is only one view."""
        return self.engine.stats()


# ---------------------------------------------------------------------------
# open-loop driver (benchmarks, tests, --serve-smoke)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpenLoopRun:
    completed: List[Session]
    rejected: List[Session]
    events: List[Tuple[float, TokenEvent]]   # (driver wall time, event)
    wall_s: float

    def interleaved(self) -> bool:
        """True when token events from different sessions interleave —
        the observable signature of concurrent streaming."""
        switches = sum(1 for a, b in zip(self.events, self.events[1:])
                       if a[1].session_id != b[1].session_id)
        return switches > len({e.session_id for _, e in self.events})


async def drive_open_loop(gateway: AgentGateway, sessions: List[Session],
                          arrivals, *, time_scale: float = 1.0,
                          ) -> OpenLoopRun:
    """Submit ``sessions`` at their open-loop ``arrivals`` offsets (wall
    clock, scaled by ``time_scale``) and consume every stream to
    completion.  One asyncio task per agent — the client side of the
    paper's overlapping multi-agent arrival pattern."""
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    run = OpenLoopRun(completed=[], rejected=[], events=[], wall_s=0.0)

    async def one(sess: Session, at: float) -> None:
        delay = at * time_scale - (loop.time() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        res = await gateway.submit(sess)
        if isinstance(res, Rejected):
            run.rejected.append(sess)
            return
        async for ev in res.events():
            run.events.append((loop.time() - t0, ev))
        run.completed.append(sess)

    await asyncio.gather(*(one(s, float(a))
                           for s, a in zip(sessions, arrivals)))
    run.wall_s = loop.time() - t0
    return run
