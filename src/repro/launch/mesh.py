"""Production mesh construction (multi-pod dry-run contract).

Defined as functions (never module-level constants) so importing this
module never touches JAX device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_pd_split_meshes(*, multi_pod: bool = False, decode_frac: float = 0.5):
    """Beyond-paper spatial PD disaggregation: split the device grid into
    a decode sub-mesh and a prefill sub-mesh (DESIGN.md §2, last row).
    Splitting is along the data axis so each sub-mesh keeps the full
    model-parallel dimension."""
    import numpy as np
    devs = np.asarray(jax.devices())
    if multi_pod:
        grid = devs[:512].reshape(2, 16, 16)
        k = max(1, int(round(16 * decode_frac)))
        dec = jax.sharding.Mesh(grid[:, :k, :], ("pod", "data", "model"))
        pre = jax.sharding.Mesh(grid[:, k:, :], ("pod", "data", "model"))
    else:
        grid = devs[:256].reshape(16, 16)
        k = max(1, int(round(16 * decode_frac)))
        dec = jax.sharding.Mesh(grid[:k, :], ("data", "model"))
        pre = jax.sharding.Mesh(grid[k:, :], ("data", "model"))
    return dec, pre
