import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, capture memory/cost analysis and the roofline
terms.  MUST be run as a module: PYTHONPATH=src python -m repro.launch.dryrun

The XLA_FLAGS line above precedes every other import because JAX locks
the device count at first backend initialisation (dry-run contract §0).

Step functions per shape kind:
  train_4k     -> full train_step (loss + grads + AdamW update)
  prefill_32k  -> forward_cold (cold-prefill serving step, last logits)
  decode_32k   -> forward_decode against a seq_len KV cache (1 new token)
  long_500k    -> forward_decode; SSM/hybrid native, SWA window for the
                  dense archs (DESIGN.md §5), skip for encoder-only.
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.flops_model import step_cost
from repro.analysis.roofline import (Roofline, model_flops_for,
                                     parse_collectives)
from repro.configs.base import (ASSIGNED_ARCHS, INPUT_SHAPES, InputShape,
                                ModelConfig, get_config)
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import (cache_shape, forward_cold, forward_decode,
                          group_layout, params_shape)
from repro.training.optimizer import AdamWConfig, OptState
from repro.training.train_step import make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# bf16 optimizer state for the giants so train_4k fits HBM
BF16_OPT_ARCHS = {"mixtral-8x22b", "jamba-1.5-large-398b"}


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend != "none":
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype),
                     "labels": jax.ShapeDtypeStruct((B, S), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return batch
    return {"tokens": jax.ShapeDtypeStruct((B,), i32),
            "lengths": jax.ShapeDtypeStruct((B,), i32)}


def _struct(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    ok: bool
    compile_s: float = 0.0
    error: str = ""
    memory: Optional[dict] = None
    flops: float = 0.0              # analytic (scan-aware) global FLOPs
    bytes_accessed: float = 0.0     # analytic global HBM bytes
    hlo_flops_per_iter: float = 0.0  # raw cost_analysis (body counted once)
    hlo_bytes_per_iter: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: Optional[dict] = None
    model_flops: float = 0.0
    skipped: bool = False
    skip_reason: str = ""


def _memory_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_per_device_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def build_step(cfg: ModelConfig, shape: InputShape, mesh, dtype=jnp.bfloat16,
               kv_quant: bool = False, seqpar: bool = False):
    """Returns (jitted_fn, example_args_as_structs).

    ``kv_quant``/``seqpar``: the §Perf hillclimb variants (int8 KV cache;
    shard_map sequence-parallel flash decode)."""
    policy = shd.auto_policy(cfg)
    pspecs = shd.param_specs(cfg, mesh, policy)
    bspecs = shd.batch_specs(cfg, mesh, shape)
    params_s = params_shape(cfg, dtype)
    batch_s = input_specs(cfg, shape, dtype)
    # MoE dispatch runs shard-local over the data(+pod) axes
    dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                      if a in ("pod", "data")]))
    tokens_total = shape.global_batch * (shape.seq_len
                                         if shape.kind != "decode" else 1)
    moe_shards = dp if tokens_total % dp == 0 else 1

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            state_dtype=jnp.bfloat16 if cfg.name in BF16_OPT_ARCHS
            else jnp.float32)
        n_params = cfg.param_count()
        microbatches = 8 if n_params > 5e10 else (2 if n_params > 2e9 else 1)
        step = make_train_step(cfg, opt_cfg, moe_mode="gmm", remat=True,
                               moe_shards=moe_shards, ce_chunk=512,
                               microbatches=microbatches)
        ospecs = shd.opt_state_specs(pspecs)
        opt_s = OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, opt_cfg.state_dtype), params_s),
            v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, opt_cfg.state_dtype), params_s))
        in_shardings = (shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                        {k: shd.named(mesh, bspecs[k]) for k in batch_s})
        out_shardings = (shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                         None)
        fn = jax.jit(step, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=(0, 1))
        return fn, (params_s, opt_s, batch_s)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return forward_cold(params, cfg, batch.get("tokens"),
                                embeds=batch.get("embeds"), moe_mode="gmm",
                                moe_shards=moe_shards)
        in_shardings = (shd.named(mesh, pspecs),
                        {k: shd.named(mesh, bspecs[k]) for k in batch_s})
        fn = jax.jit(prefill_step, in_shardings=in_shardings)
        return fn, (params_s, batch_s)

    # decode
    window = cfg.attention_window_for(shape.name)
    seqpar = seqpar and cfg.num_heads > 0
    cspecs = shd.cache_specs(cfg, mesh, shape, kv_quant=kv_quant,
                             seqpar=seqpar)
    cache_s = _struct(cache_shape(cfg, shape.global_batch, shape.seq_len,
                                  dtype, kv_quant=kv_quant))

    from repro.distributed.context import SPMDContext
    seq_ctx = None
    if seqpar:
        if shape.global_batch < 8:     # long_500k: whole mesh = seq axis
            seq_ctx = SPMDContext(mesh=mesh,
                                  dp_axes=tuple(mesh.axis_names),
                                  tp_axis="model")
        else:                          # decode_32k: batch dp, seq model
            ba = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            seq_ctx = SPMDContext(mesh=mesh, dp_axes=("model",),
                                  tp_axis="model", batch_axes=ba)

    def decode_step(params, cache, tokens, lengths):
        logits, new_cache, new_len = forward_decode(
            params, cfg, tokens, cache, lengths, moe_mode="gmm",
            moe_shards=moe_shards, seq_parallel=seq_ctx,
            window_override=window if window else None)
        return logits, new_cache, new_len

    in_shardings = (shd.named(mesh, pspecs), shd.named(mesh, cspecs),
                    shd.named(mesh, bspecs["tokens"]),
                    shd.named(mesh, bspecs["lengths"]))
    out_shardings = (None, shd.named(mesh, cspecs), None)
    fn = jax.jit(decode_step, in_shardings=in_shardings,
                 out_shardings=out_shardings, donate_argnums=(1,))
    return fn, (params_s, cache_s, batch_s["tokens"], batch_s["lengths"])


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            dtype=jnp.bfloat16, save: bool = True, verbose: bool = True,
            kv_quant: bool = False, seqpar: bool = False,
            tag: str = "") -> DryrunResult:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256

    if not cfg.supports_shape(shape_name):
        reason = ("encoder-only architecture has no decode phase"
                  if cfg.encoder_only else "unsupported")
        return DryrunResult(arch, shape_name, mesh_name, chips, ok=True,
                            skipped=True, skip_reason=reason)

    mesh = make_production_mesh(multi_pod=multi_pod)
    res = DryrunResult(arch, shape_name, mesh_name, chips, ok=False)
    from repro.distributed.context import spmd_context, spmd_for_mesh
    try:
        t0 = time.time()
        with mesh, spmd_context(spmd_for_mesh(
                mesh, fsdp=__import__('repro.distributed.sharding',
                                      fromlist=['auto_policy']
                                      ).auto_policy(cfg).fsdp)):
            fn, args = build_step(cfg, shape, mesh, dtype,
                                  kv_quant=kv_quant, seqpar=seqpar)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        res.compile_s = time.time() - t0
        res.memory = _memory_dict(compiled)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        res.hlo_flops_per_iter = float(cost.get("flops", 0.0))
        res.hlo_bytes_per_iter = float(cost.get("bytes accessed", 0.0))
        policy = shd.auto_policy(cfg)
        dp = 32 if multi_pod else 16
        sc = step_cost(cfg, shape, dp_size=dp, fsdp=policy.fsdp,
                       window=cfg.attention_window_for(shape_name),
                       kv_bytes=1 if kv_quant else 2)
        res.flops = sc.total_flops
        res.bytes_accessed = sc.hbm_bytes
        G, _, _ = group_layout(cfg)
        coll = parse_collectives(compiled.as_text(), loop_trip_count=G)
        res.collective_bytes = coll.total_bytes
        res.collective_detail = {"bytes": coll.bytes_by_kind,
                                 "count": coll.count_by_kind}
        res.model_flops = model_flops_for(cfg, shape,
                                          is_train=shape.kind == "train")
        res.ok = True
        if verbose:
            mem = res.memory.get("total_per_device_bytes", 0) / 1e9
            print(f"[OK] {arch} x {shape_name} x {mesh_name}: "
                  f"compile {res.compile_s:.1f}s, mem/device {mem:.2f} GB, "
                  f"flops {res.flops:.3e}, coll {res.collective_bytes:.3e} B",
                  flush=True)
    except Exception as e:  # noqa: BLE001
        res.error = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: "
                  f"{res.error[:300]}", flush=True)
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = OUT_DIR / f"{arch}_{shape_name}_{mesh_name}{suffix}.json"
        path.write_text(json.dumps(dataclasses.asdict(res), indent=1,
                                   default=float))
    return res


def roofline_from_result(res: DryrunResult, cfg: ModelConfig) -> Roofline:
    return Roofline(arch=res.arch, shape=res.shape, mesh=res.mesh,
                    chips=res.chips,
                    hlo_flops=res.flops, hlo_bytes=res.bytes_accessed,
                    collective_bytes=res.collective_bytes / res.chips,
                    model_flops=res.model_flops)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes[args.mesh]:
                r = run_one(arch, shape, multi_pod=mp)
                failures += 0 if r.ok else 1
    print(f"dryrun complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
