"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots an AgentServe engine for the reduced variant of the selected
architecture and serves a multi-agent ToolBench-like workload, printing
the per-policy report (the paper's Fig-5-style output)."""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import ServingReport, SLOThresholds
from repro.serving.policies import POLICIES
from repro.serving.workload import make_workload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--policy", default="agentserve",
                    choices=sorted(POLICIES))
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--workload", default="react",
                    choices=["react", "plan_execute"])
    ap.add_argument("--token-scale", type=float, default=0.125)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", action="store_true",
                    help="run every policy on the same workload")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=max(args.agents + 2, 6), max_seq=1024,
                        cycle_budget=160, granularity=16,
                        control_interval_s=0.1)
    policies = sorted(POLICIES) if args.compare else [args.policy]
    print(ServingReport.HEADER)
    for policy in policies:
        sessions = make_workload(
            args.agents, workload=args.workload,
            vocab_size=cfg.vocab_size, token_scale=args.token_scale,
            num_system_prompts=1, seed=args.seed)
        eng = ServingEngine(cfg, params, POLICIES[policy], ecfg)
        rep = eng.run(sessions)
        print(rep.row(), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
