"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Two modes:

  * closed-loop (default): boots an AgentServe engine for the reduced
    variant of the selected architecture and serves a multi-agent
    ToolBench-like workload, printing the per-policy report (the
    paper's Fig-5-style output);
  * online (``--serve``): boots the asyncio gateway (DESIGN.md §6) and
    exposes a minimal stdlib HTTP/SSE front —

        GET  /healthz      liveness
        GET  /stats        unified telemetry registry snapshot (JSON)
        GET  /metrics      the same registry, Prometheus text format
        POST /v1/session   submit an agent session; streams one
                           ``data: {...}`` SSE line per token, a final
                           ``event: done`` record, or HTTP 429 when the
                           admission watermark sheds the request.

    ``--serve-smoke`` boots the same server on an ephemeral port,
    drives it with an in-process asyncio client at an open-loop Poisson
    rate, prints the open-loop report row, and exits — the CI gateway
    smoke path.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
from typing import Dict, List, Tuple

import numpy as np

import jax

from repro.configs.base import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.gateway import AgentGateway, GatewayConfig, Rejected
from repro.serving.metrics import (OpenLoopReport, ServingReport,
                                   SLOThresholds, build_open_loop_report)
from repro.serving.policies import PLANNERS, POLICIES
from repro.serving.telemetry import (parse_prometheus_text,
                                     reconstruct_latency,
                                     validate_trace_events)
from repro.serving.workload import (SPECS, make_session, make_workload,
                                    poisson_arrivals)


# ---------------------------------------------------------------------------
# HTTP/SSE front (stdlib asyncio only — no extra deps)
# ---------------------------------------------------------------------------

def _http_resp(status: int, body: bytes, ctype: str = "application/json",
               ) -> bytes:
    reason = {200: "OK", 404: "Not Found", 429: "Too Many Requests",
              400: "Bad Request"}.get(status, "OK")
    return (f"HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
            ).encode() + body


def _json_resp(status: int, obj) -> bytes:
    return _http_resp(status, json.dumps(obj).encode())


def _session_from_spec(spec: Dict, mcfg, default_token_scale: float):
    """Build a scripted agent session from a client JSON spec:
    ``{"workload": "react", "seed": 7, "token_scale": 0.1,
    "slo_class": "interactive", "deadline_s": 30.0}``.  The session_id
    is assigned by the gateway at admission; ``slo_class`` matters under
    ``--policy priority`` (interactive requests preempt batch cold
    prefills); ``deadline_s`` (relative seconds, optional) arms an
    engine-enforced SLO deadline — past it the session is aborted and
    its stream ends with an ``event: aborted`` record."""
    workload = spec.get("workload", "react")
    if workload not in SPECS:
        raise ValueError(f"unknown workload {workload!r}")
    slo_class = spec.get("slo_class", "batch")
    if slo_class not in ("interactive", "batch"):
        raise ValueError(f"unknown slo_class {slo_class!r}")
    seed = int(spec.get("seed", 0))
    scale = float(spec.get("token_scale", default_token_scale))
    rng = np.random.default_rng(seed)
    sess = make_session(-1, SPECS[workload], rng, mcfg.vocab_size,
                        token_scale=scale)
    sess.slo_class = slo_class
    return sess


async def _read_request(reader) -> Tuple[str, str, Dict[str, str], bytes]:
    line = await reader.readline()
    if not line:
        raise ConnectionError("empty request")
    parts = line.decode("latin1").split()
    if len(parts) < 2:
        raise ValueError(f"bad request line {line!r}")
    method, path = parts[0], parts[1]
    headers: Dict[str, str] = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        headers[k.strip().lower()] = v.strip()
    body = b""
    n = int(headers.get("content-length", "0") or 0)
    if n:
        body = await reader.readexactly(n)
    return method, path, headers, body


async def handle_connection(gateway: AgentGateway, mcfg,
                            default_token_scale: float,
                            reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
    try:
        try:
            method, path, _, body = await _read_request(reader)
        except (ValueError, ConnectionError, asyncio.IncompleteReadError):
            return
        if method == "GET" and path == "/healthz":
            writer.write(_json_resp(200, {"ok": True}))
        elif method == "GET" and path == "/stats":
            writer.write(_json_resp(200, gateway.stats()))
        elif method == "GET" and path == "/metrics":
            text = gateway.engine.telemetry.registry.prometheus_text()
            writer.write(_http_resp(200, text.encode(),
                                    "text/plain; version=0.0.4"))
        elif method == "POST" and path == "/v1/session":
            try:
                spec = json.loads(body or b"{}")
                if not isinstance(spec, dict):
                    raise ValueError("request body must be a JSON object")
                sess = _session_from_spec(spec, mcfg, default_token_scale)
                deadline = spec.get("deadline_s")
                deadline = None if deadline is None else float(deadline)
            except (ValueError, KeyError, TypeError) as e:
                writer.write(_json_resp(400, {"error": str(e)}))
                await writer.drain()
                return
            res = await gateway.submit(sess, deadline_s=deadline)
            if isinstance(res, Rejected):
                writer.write(_json_resp(429, {
                    "error": res.reason, "occupancy": res.occupancy}))
                await writer.drain()
                return
            writer.write(b"HTTP/1.1 200 OK\r\n"
                         b"Content-Type: text/event-stream\r\n"
                         b"Cache-Control: no-cache\r\n"
                         b"Connection: close\r\n\r\n")
            await writer.drain()
            # disconnect watcher: the request body is fully consumed, so
            # any further read completing means the peer closed its end
            # of the connection — cancel the session so the engine
            # reclaims its slot/pages promptly (DESIGN.md §10)
            watcher = asyncio.get_running_loop().create_task(reader.read())
            aborted_ev = None
            try:
                async for ev in res.events():
                    if watcher.done():
                        res.cancel()     # client went away mid-stream
                    if ev.error:
                        aborted_ev = ev
                    writer.write(b"data: "
                                 + json.dumps(dataclasses.asdict(ev)).encode()
                                 + b"\n\n")
                    await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                res.cancel()             # write side saw the disconnect
                raise
            finally:
                watcher.cancel()
            if aborted_ev is not None:
                writer.write(b"event: aborted\ndata: "
                             + json.dumps({
                                 "session_id": res.session_id,
                                 "reason": aborted_ev.abort_reason,
                                 "tokens": len(res.received) - 1}).encode()
                             + b"\n\n")
            else:
                writer.write(b"event: done\ndata: "
                             + json.dumps({
                                 "session_id": res.session_id,
                                 "tokens": len(res.received)}).encode()
                             + b"\n\n")
        else:
            writer.write(_json_resp(404, {"error": f"no route {path}"}))
        await writer.drain()
    except (ConnectionResetError, BrokenPipeError):
        pass                             # client went away mid-stream
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


# ---------------------------------------------------------------------------
# asyncio SSE client (smoke driver + tests + benchmarks)
# ---------------------------------------------------------------------------

async def sse_submit(host: str, port: int, spec: Dict,
                     ) -> Tuple[int, List[Dict]]:
    """POST one session spec and consume its SSE stream.  Returns
    (http_status, token event dicts)."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(spec).encode()
    writer.write((f"POST /v1/session HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    status_line = await reader.readline()
    status = int(status_line.split()[1])
    while (await reader.readline()) not in (b"\r\n", b"\n", b""):
        pass                             # skip response headers
    events: List[Dict] = []
    if status == 200:
        while True:
            line = await reader.readline()
            if not line:
                break
            line = line.strip()
            if line in (b"event: done", b"event: aborted"):
                await reader.readline()  # the terminal data record
                break
            if line.startswith(b"data: "):
                events.append(json.loads(line[len(b"data: "):]))
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    return status, events


async def sse_get(host: str, port: int, path: str) -> Tuple[int, Dict]:
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    n = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        if h.lower().startswith(b"content-length:"):
            n = int(h.split(b":")[1])
    body = json.loads(await reader.readexactly(n)) if n else {}
    writer.close()
    await writer.wait_closed()
    return status, body


async def http_get_text(host: str, port: int, path: str,
                        ) -> Tuple[int, str]:
    """Raw-text GET (the ``/metrics`` scrape — Prometheus text is not
    JSON, so ``sse_get`` cannot fetch it)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    n = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        if h.lower().startswith(b"content-length:"):
            n = int(h.split(b":")[1])
    body = (await reader.readexactly(n)).decode() if n else ""
    writer.close()
    await writer.wait_closed()
    return status, body


def _export_trace(engine, path: str) -> None:
    """Dump the run's span timeline as Chrome/Perfetto trace_event JSON
    (``--trace-out``), re-validated on the way out."""
    if not path:
        return
    n = engine.telemetry.export_trace(path)
    with open(path) as f:
        validate_trace_events(json.load(f))
    print(f"trace: {n} events -> {path} (open in ui.perfetto.dev)",
          flush=True)


# ---------------------------------------------------------------------------
# gateway boot
# ---------------------------------------------------------------------------

def _build_engine(args, *, max_wall_s: float = 300.0,
                  ) -> Tuple[ServingEngine, object]:
    """One engine construction for both the closed-loop and online
    paths — they must not silently diverge in shapes/budget."""
    cfg = get_smoke_config(args.arch)
    if getattr(args, "kv_layout", "slab") != "slab":
        cfg = dataclasses.replace(cfg, kv_layout=args.kv_layout,
                                  kv_page_size=args.kv_page_size)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=max(args.agents + 2, 6), max_seq=1024,
                        cycle_budget=160, granularity=16,
                        control_interval_s=0.1, max_wall_s=max_wall_s)
    return ServingEngine(cfg, params, PLANNERS[args.policy], ecfg), cfg


def build_gateway(args) -> Tuple[AgentGateway, object]:
    engine, cfg = _build_engine(args, max_wall_s=float("inf"))
    gcfg = GatewayConfig(high_watermark=args.high_watermark,
                         admission=args.admission,
                         tool_policy=args.tool_policy)
    return AgentGateway(engine, gcfg), cfg


async def _serve(args) -> int:
    gateway, mcfg = build_gateway(args)
    await gateway.start()
    server = await asyncio.start_server(
        lambda r, w: handle_connection(gateway, mcfg, args.token_scale,
                                       r, w),
        args.host, args.port)
    port = server.sockets[0].getsockname()[1]
    print(f"gateway serving on http://{args.host}:{port} "
          f"(policy={args.policy}, watermark={args.high_watermark})",
          flush=True)
    try:
        async with server:
            await server.serve_forever()
    except asyncio.CancelledError:
        pass
    finally:
        await gateway.stop(timeout_s=5.0)
        _export_trace(gateway.engine, args.trace_out)
    return 0


async def _serve_smoke(args) -> int:
    """Boot the SSE server on an ephemeral port, drive it with an
    asyncio client cohort at an open-loop Poisson rate, and print the
    open-loop report — end-to-end over real sockets."""
    gateway, mcfg = build_gateway(args)
    await gateway.start()
    server = await asyncio.start_server(
        lambda r, w: handle_connection(gateway, mcfg, args.token_scale,
                                       r, w),
        args.host, 0)
    port = server.sockets[0].getsockname()[1]
    print(f"smoke server on {args.host}:{port}", flush=True)

    arrivals = poisson_arrivals(args.rate, args.agents, seed=args.seed)
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    statuses: List[int] = []
    all_events: List[Tuple[float, Dict]] = []

    async def one(i: int, at: float) -> None:
        delay = at - (loop.time() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        status, events = await sse_submit(
            args.host, port, {"workload": args.workload, "seed": args.seed + i,
                              "token_scale": args.token_scale})
        statuses.append(status)
        all_events.extend((loop.time() - t0, e) for e in events)

    await asyncio.gather(*(one(i, a) for i, a in enumerate(arrivals)))
    wall = loop.time() - t0

    # telemetry surfaces, checked over the live socket (DESIGN.md §11):
    # /metrics parses as Prometheus text and the three stats views —
    # engine, gateway, HTTP — expose identical key sets
    m_status, m_text = await http_get_text(args.host, port, "/metrics")
    assert m_status == 200, f"/metrics returned {m_status}"
    samples = parse_prometheus_text(m_text)
    assert samples, "/metrics served no samples"
    s_status, http_stats = await sse_get(args.host, port, "/stats")
    assert s_status == 200, f"/stats returned {s_status}"
    assert (set(http_stats) == set(gateway.stats())
            == set(gateway.engine.stats())), "stats key drift"
    print(f"/metrics: {len(samples)} samples, "
          f"/stats: {len(http_stats)} keys (views agree)", flush=True)

    await gateway.stop(timeout_s=30.0)
    server.close()
    await server.wait_closed()

    ok = statuses.count(200)
    shed = statuses.count(429)
    sids = {e["session_id"] for _, e in all_events}
    print(f"agents={args.agents} rate={args.rate}/s wall={wall:.2f}s "
          f"streams_ok={ok} shed_429={shed} "
          f"tokens={len(all_events)} sessions_streamed={len(sids)}",
          flush=True)
    done = list(gateway.completed_sessions)
    rep = build_open_loop_report(
        args.policy, done, wall, args.rate, rejected=shed,
        thresholds=SLOThresholds(ttft_s=10.0, tpot_s=2.0),
        aborted_sessions=list(gateway.failed_sessions))
    print(OpenLoopReport.HEADER)
    print(rep.row(), flush=True)
    assert ok + shed == args.agents, "every request must resolve"
    assert ok > 0 and len(all_events) > 0, "no tokens streamed"
    assert len(done) == ok, "every admitted session must finish"

    # timeline export + the acceptance cross-check: per-session spans
    # must reconstruct TTFT/TPOT within 1% of metrics.py's values
    tracer = gateway.engine.telemetry.tracer
    if tracer is not None and done:
        from repro.serving.metrics import collect_tpots, collect_ttfts
        span_ttfts, span_tpot = reconstruct_latency(tracer.spans)
        m_ttfts = collect_ttfts(done)
        m_tpots = collect_tpots(done)
        if m_ttfts:
            a, b = float(np.mean(span_ttfts)), float(np.mean(m_ttfts))
            assert abs(a - b) <= 0.01 * b, f"span TTFT {a} vs {b}"
        if m_tpots:
            a, b = span_tpot, float(np.mean(m_tpots))
            assert abs(a - b) <= 0.01 * b, f"span TPOT {a} vs {b}"
        assert tracer.open_span_count() == 0, \
            f"leaked spans: {tracer.open_spans()}"
        print(f"span reconstruction OK: {len(span_ttfts)} TTFTs, "
              f"mean TPOT {span_tpot * 1e3:.2f}ms within 1% of metrics",
              flush=True)
    _export_trace(gateway.engine, args.trace_out)
    return 0


# ---------------------------------------------------------------------------
# closed-loop mode (unchanged Fig-5 path)
# ---------------------------------------------------------------------------

def _closed_loop(args) -> int:
    policies = sorted(POLICIES) if args.compare else [args.policy]
    print(ServingReport.HEADER)
    for policy in policies:
        eng, cfg = _build_engine(
            argparse.Namespace(**{**vars(args), "policy": policy}))
        sessions = make_workload(
            args.agents, workload=args.workload,
            vocab_size=cfg.vocab_size, token_scale=args.token_scale,
            num_system_prompts=1, seed=args.seed)
        rep = eng.run(sessions)
        print(rep.row(), flush=True)
        # --compare reruns per policy; the trace captures the last run
        _export_trace(eng, args.trace_out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--policy", default="agentserve",
                    choices=sorted(PLANNERS))
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--workload", default="react",
                    choices=["react", "plan_execute"])
    ap.add_argument("--token-scale", type=float, default=0.125)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", action="store_true",
                    help="run every policy on the same workload")
    # online gateway mode
    ap.add_argument("--serve", action="store_true",
                    help="boot the online HTTP/SSE gateway")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="boot the gateway and drive it with an in-process "
                         "open-loop client cohort, then exit")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8077)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--high-watermark", type=int, default=8)
    ap.add_argument("--admission", default="reject",
                    choices=["reject", "queue"])
    ap.add_argument("--tool-policy", default="hold",
                    choices=["hold", "release"])
    ap.add_argument("--kv-layout", default="slab",
                    choices=["slab", "paged"],
                    help="KV cache layout (DESIGN.md §8): paged enables "
                         "zero-copy prefix sharing and park/unpark")
    ap.add_argument("--kv-page-size", type=int, default=64)
    ap.add_argument("--trace-out", default="",
                    help="write the run's span timeline as Chrome/"
                         "Perfetto trace_event JSON to this path "
                         "(load in ui.perfetto.dev or chrome://tracing)")
    args = ap.parse_args(argv)

    if args.serve_smoke:
        return asyncio.run(_serve_smoke(args))
    if args.serve:
        return asyncio.run(_serve(args))
    return _closed_loop(args)


if __name__ == "__main__":
    sys.exit(main())
