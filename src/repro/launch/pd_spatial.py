import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Spatial PD disaggregation dry-run (DESIGN.md §2, last mapping row).

The multi-chip extension of the paper's Green-Context idea: instead of
partitioning one device's compute, partition the *device grid* — a
decode sub-mesh and a prefill sub-mesh, both keeping the full
model-parallel dimension, with the slot grid realised as discrete
splits of the data axis (k : 16-k).  Run as

    PYTHONPATH=src python -m repro.launch.pd_spatial --arch llama3.2-3b

This proves (by lower+compile on both sub-meshes) that the same model
weights can serve decode and prefill *concurrently* on disjoint chips —
the true spatial-isolation semantics the paper gets from Green Contexts,
which the single-chip temporal engine can only approximate.
"""
import argparse
import dataclasses as _dc
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, get_config
from repro.distributed import sharding as shd
from repro.distributed.context import spmd_context, spmd_for_mesh
from repro.launch.dryrun import OUT_DIR, _memory_dict, build_step
from repro.launch.mesh import make_pd_split_meshes


def run_pd_spatial(arch: str, *, decode_frac: float = 0.5,
                   multi_pod: bool = False, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    dec_mesh, pre_mesh = make_pd_split_meshes(multi_pod=multi_pod,
                                              decode_frac=decode_frac)
    out = {"arch": arch, "decode_frac": decode_frac,
           "decode_chips": dec_mesh.devices.size,
           "prefill_chips": pre_mesh.devices.size}

    # decode slice batch scales with its sub-mesh share
    dshape = INPUT_SHAPES["decode_32k"]
    ddp = dec_mesh.shape.get("data", 1) * dec_mesh.shape.get("pod", 1)
    dshape = _dc.replace(dshape, global_batch=max(8 * ddp, 8))
    pshape = INPUT_SHAPES["prefill_32k"]
    pdp = pre_mesh.shape.get("data", 1) * pre_mesh.shape.get("pod", 1)
    pshape = _dc.replace(pshape, global_batch=max(2 * pdp, 2))

    for name, mesh, shape in [("decode", dec_mesh, dshape),
                              ("prefill", pre_mesh, pshape)]:
        t0 = time.time()
        with mesh, spmd_context(spmd_for_mesh(
                mesh, fsdp=shd.auto_policy(cfg).fsdp)):
            fn, args = build_step(cfg, shape, mesh, jnp.bfloat16)
            compiled = fn.lower(*args).compile()
        mem = _memory_dict(compiled)
        out[name] = {"ok": True, "compile_s": time.time() - t0,
                     "batch": shape.global_batch,
                     "mem_gb_per_device":
                         mem.get("total_per_device_bytes", 0) / 1e9}
        if verbose:
            print(f"[OK] pd_spatial {arch} {name}: "
                  f"{mesh.devices.size} chips, batch {shape.global_batch}, "
                  f"mem/dev {out[name]['mem_gb_per_device']:.2f} GB",
                  flush=True)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"pd_spatial_{arch}.json").write_text(
        json.dumps(out, indent=1, default=float))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--decode-frac", type=float, default=0.5)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    run_pd_spatial(args.arch, decode_frac=args.decode_frac,
                   multi_pod=args.multi_pod)
    return 0


if __name__ == "__main__":
    sys.exit(main())
