"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real training steps on the local device(s).  For the production
mesh this is the same ``make_train_step`` the dry-run lowers; locally it
trains the reduced variant of the selected architecture on the synthetic
corpus (the end-to-end driver of examples/train_slm.py).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, get_smoke_config
from repro.models import init_params
from repro.models.common import count_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (production) config instead of the "
                         "reduced variant — requires real accelerators")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--resume", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config \
        else get_smoke_config(args.arch)
    print(f"# arch={cfg.name} family={cfg.family} layers={cfg.num_layers} "
          f"d_model={cfg.d_model}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"# params: {count_params(params) / 1e6:.2f}M")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    opt = init_opt_state(opt_cfg, params)
    start = 0
    if args.resume:
        params, opt, start = load_checkpoint(args.resume, params, opt)
        print(f"# resumed from {args.resume} at step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, moe_mode="dense",
                                      remat=True))
    data = SyntheticCorpus(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch)).batches()

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        if cfg.frontend != "none":
            # modality stub: frames/patches instead of token ids
            B, S = batch["tokens"].shape
            batch = {"embeds": jax.random.normal(
                jax.random.PRNGKey(step), (B, S, cfg.d_model)),
                "labels": batch["tokens"]}
        params, opt, stats = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            tps = (step - start + 1) * args.batch * args.seq \
                / max(time.time() - t0, 1e-9)
            print(f"step {step:5d}  loss {float(stats['loss']):.4f}  "
                  f"ce {float(stats['ce']):.4f}  "
                  f"gnorm {float(stats['grad_norm']):.3f}  "
                  f"tok/s {tps:.0f}", flush=True)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, opt, args.steps,
                        meta={"arch": cfg.name})
        print(f"# saved {args.checkpoint}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
