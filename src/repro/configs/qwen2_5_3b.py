"""Qwen2.5-3B — the paper's own evaluation SLM (§IV-A).

Source: [arXiv:2501.15383] (Qwen2.5 technical report).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="arXiv:2501.15383",
)
