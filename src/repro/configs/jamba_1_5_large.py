"""Jamba-1.5-Large (398B) — hybrid Mamba+attention 1:7, MoE 16e top-2.

Source: [arXiv:2403.19887]. Within each period of 8 layers, one is
attention (index 4 in the published config — we use the middle slot) and
7 are Mamba; MoE replaces the MLP every 2 layers.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk_size=256),
    hybrid_period=8,
    hybrid_attn_index=4,
    source="arXiv:2403.19887",
)
