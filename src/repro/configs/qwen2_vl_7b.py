"""Qwen2-VL-7B — VLM decoder with M-RoPE; vision tower is a sanctioned stub.

Source: [arXiv:2409.12191]. ``input_specs`` provides precomputed patch
embeddings; this config is the language/decoder backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
    source="arXiv:2409.12191",
)
