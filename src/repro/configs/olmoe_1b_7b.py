"""OLMoE-1B-7B — MoE 64 experts top-8 (1B active / 7B total).

Source: [arXiv:2409.02060]. d_ff=1024 is the per-expert FFN width.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, every=1),
    source="arXiv:2409.02060",
)
