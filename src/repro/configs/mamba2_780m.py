"""Mamba2-780m — attention-free SSM with SSD (state-space duality).

Source: [arXiv:2405.21060]. d_ff=0: Mamba-2 blocks contain the mixing
and gating; there is no separate MLP.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
