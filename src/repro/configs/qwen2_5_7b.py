"""Qwen2.5-7B — the paper's own evaluation SLM (§IV-A).

Source: [arXiv:2501.15383].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope_theta=1_000_000.0,
    source="arXiv:2501.15383",
)
