"""HuBERT X-Large — encoder-only audio transformer (wav2vec2 arch).

Source: [arXiv:2106.07447]. The conv feature extractor is a sanctioned
stub: ``input_specs`` provides precomputed frame embeddings.
vocab_size=504 is the masked-unit codebook size (k-means targets).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    frontend="audio",
    act="gelu",
    source="arXiv:2106.07447",
)
