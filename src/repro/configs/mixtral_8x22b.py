"""Mixtral 8x22B — MoE 8 experts top-2, GQA, sliding-window attention.

Source: [arXiv:2401.04088] (Mixtral of Experts; 8x22B scale per assignment).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    moe=MoEConfig(num_experts=8, top_k=2, every=1),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)
