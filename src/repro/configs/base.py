"""Config system: architecture + input-shape registries.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` with the exact published dimensions (source cited in the
module docstring).  ``reduced()`` derives the CPU-smoke-test variant of
the same family (≤2 layers, d_model ≤ 512, ≤4 experts) as required by
the reproduction contract.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned; fixed by the reproduction contract)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # Apply an MoE MLP every `every` layers (1 = every layer). Jamba uses 2.
    every: int = 1
    # Router auxiliary load-balance loss weight (train path).
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) hyper-parameters [arXiv:2405.21060]."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def num_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int          # 0 for attention-free architectures
    num_kv_heads: int
    d_ff: int               # 0 for attention-free (pure SSM) architectures
    vocab_size: int
    head_dim: int = 0       # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: within each group of `hybrid_period` layers, layer index
    # `hybrid_attn_index` is attention, the rest are Mamba-2 (Jamba 1:7).
    hybrid_period: int = 0
    hybrid_attn_index: int = 0
    sliding_window: int = 0      # 0 = full attention (mixtral: 4096)
    encoder_only: bool = False   # hubert: bidirectional, no decode phase
    rope_theta: float = 10_000.0
    mrope: bool = False          # qwen2-vl M-RoPE (3 rotary sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    frontend: str = "none"       # none | audio | vision  (sanctioned stubs)
    # Serving prefill/resume attention path (DESIGN.md §4): "xla" = the
    # pure-JAX blocked scan (reference; streams all max_seq KV tiles per
    # chunk); "pallas" = the cache-aware Pallas kernel with scalar-
    # prefetched length/offset tile pruning (interpret-mode on CPU).
    prefill_kernel: str = "xla"
    # Serving decode attention path under the paged layout: "xla" =
    # gather pages then run the blocked reference; "pallas" = the
    # block-table flash-decode kernel (DESIGN.md §8).
    decode_kernel: str = "xla"
    # Serving KV-cache layout (DESIGN.md §8): "slab" = per-slot
    # contiguous [num_slots, max_seq] stripes (reference / parity
    # oracle); "paged" = flat page arena [num_pages, page_size] with
    # per-session block tables, refcounted page sharing and COW.
    kv_layout: str = "slab"
    kv_page_size: int = 64       # paged layout: tokens per page (= the
    #                              kernels' block_k tile)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "swiglu"          # swiglu | gelu
    source: str = ""             # citation

    # ---- derived -----------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab rounded up to a multiple of 128 so
        the vocab dim always divides the 16-wide model axis (and TPU
        lanes).  Logits are sliced back to ``vocab_size``."""
        return -(-self.vocab_size // 128) * 128

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decode_phase(self) -> bool:
        return not self.encoder_only

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i (hybrid interleave)."""
        if self.family == "ssm":
            return "ssm"
        if self.hybrid_period:
            return "attn" if (i % self.hybrid_period) == self.hybrid_attn_index else "ssm"
        return "attn"

    def layer_has_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every) == (self.moe.every - 1)

    def supports_shape(self, shape_name: str) -> bool:
        """Contract: encoder-only skips decode; long_500k needs sub-quadratic
        (native SSM/hybrid/SWA, or the sanctioned SWA decode variant for
        dense archs — which we do implement, so dense archs run it)."""
        s = INPUT_SHAPES[shape_name]
        if s.kind == "decode" and not self.has_decode_phase:
            return False
        return True

    def attention_window_for(self, shape_name: str) -> int:
        """Effective attention window for a shape. long_500k on archs with
        no native sub-quadratic path uses the sliding-window variant."""
        if self.sliding_window:
            return self.sliding_window
        if shape_name == "long_500k" and self.family not in ("ssm", "hybrid"):
            return 8_192  # sanctioned SWA decode variant (DESIGN.md §5)
        return 0

    # ---- parameter counting (for roofline MODEL_FLOPS) ---------------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head / encoder proj
        for i in range(L):
            total += 2 * d  # norms
            if self.layer_kind(i) == "attn":
                hd = self.head_dim
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
            else:
                ssm = self.ssm or SSMConfig()
                d_in = ssm.expand * d
                nh = ssm.num_heads(d)
                # in_proj produces [z, x, B, C, dt]
                total += d * (2 * d_in + 2 * ssm.d_state + nh)
                total += ssm.d_conv * (d_in + 2 * ssm.d_state)  # conv1d
                total += nh * 2  # A_log, D
                total += d_in * d  # out_proj
            if self.d_ff:
                n_mat = 3 if self.act == "swiglu" else 2
                ff = n_mat * d * self.d_ff
                if self.layer_has_moe(i):
                    m = self.moe
                    total += d * m.num_experts  # router
                    k = m.top_k if active_only else m.num_experts
                    total += k * ff
                else:
                    total += ff
        return total


# ---------------------------------------------------------------------------
# Reduced (smoke) variants
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same family, shrunk per contract: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    d_model = min(cfg.d_model, 256)
    heads = 4 if cfg.num_heads else 0
    kv = min(max(1, cfg.num_kv_heads * 4 // max(cfg.num_heads, 1) or 1), heads) if heads else 0
    kv = kv if heads == 0 or heads % kv == 0 else 2
    period = cfg.hybrid_period
    layers = 2 if not period else period  # hybrid smoke keeps one full group
    moe = None
    if cfg.moe:
        moe = MoEConfig(num_experts=4, top_k=min(2, cfg.moe.top_k),
                        every=min(cfg.moe.every, 2),
                        aux_loss_weight=cfg.moe.aux_loss_weight)
    ssm = None
    if cfg.ssm:
        ssm = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk_size=32)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=(d_model // heads) if heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        moe=moe,
        ssm=ssm,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        mrope_sections=(8, 12, 12) if cfg.mrope else cfg.mrope_sections,
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# assigned pool + the paper's own evaluation models
ARCH_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "starcoder2-15b": "starcoder2_15b",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "mamba2-780m": "mamba2_780m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "smollm-360m": "smollm_360m",
    "llama3.2-3b": "llama3_2_3b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    # paper's own testbed models (§IV-A)
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen2.5-7b": "qwen2_5_7b",
    "llama3-8b": "llama3_8b",
}

ASSIGNED_ARCHS = list(ARCH_MODULES)[:10]


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))


def all_configs():
    return {n: get_config(n) for n in ARCH_MODULES}
