"""SmolLM-360M — llama-architecture small model (GQA kv=5).

Source: [hf:HuggingFaceTB/SmolLM-135M] family card, 360M variant dims
per assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
