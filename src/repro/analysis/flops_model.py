"""Analytic FLOPs / HBM-bytes model for the roofline terms.

Why analytic: our entire depth dimension lowers to ``lax.scan`` (one HLO
``while``), and XLA's ``cost_analysis()`` counts a while body ONCE
regardless of trip count (verified empirically — a 10-iteration scan of
a matmul reports exactly one matmul's flops).  Correcting the aggregate
number per nested loop is not possible from the single scalar XLA
returns, so the roofline uses this analytic model — exact for the
matmul-dominated terms since we authored every layer — and reports the
XLA number alongside as ``hlo_flops_per_iter`` for transparency.

Conventions: a [m,k]x[k,n] matmul is 2mkn FLOPs; backward = 2x forward;
remat recompute adds ~1 forward (2 for the 2-level sqrt scan).  Bytes
are *global* HBM traffic: per-device traffic summed over chips, so
params replicated over the data axes are counted once per replica —
that is real HBM traffic and exactly what the memory roofline term
divides by (chips x HBM_bw).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import InputShape, ModelConfig, SSMConfig

BF16 = 2


@dataclasses.dataclass
class StepCost:
    fwd_flops: float          # one forward pass, global
    total_flops: float        # incl. backward/remat/optimizer for train
    hbm_bytes: float          # global HBM traffic for the step
    detail: Dict[str, float]


def _attn_ctx(shape: InputShape, window: int) -> float:
    """Average attended context length per query token."""
    if shape.kind == "decode":
        L = shape.seq_len
        return float(min(L, window) if window else L)
    S = shape.seq_len
    if window and S > 2 * window:
        return float(window)
    return S / 2.0


def _layer_flops_per_token(cfg: ModelConfig, i: int, ctx: float,
                           moe_capacity: float = 1.25) -> float:
    d = cfg.d_model
    f = 0.0
    if cfg.layer_kind(i) == "attn":
        hd, H, Hk = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
        f += 2 * d * H * hd          # wq
        f += 2 * d * Hk * hd * 2     # wk, wv
        f += 2 * H * hd * d          # wo
        f += 2 * H * hd * ctx * 2    # scores + values
    else:
        ssm = cfg.ssm or SSMConfig()
        d_in, N = ssm.expand * d, ssm.d_state
        nh, hd, Q = ssm.num_heads(d), ssm.head_dim, ssm.chunk_size
        f += 2 * d * (2 * d_in + 2 * N + nh)     # z, x, B, C, dt projections
        f += 2 * ssm.d_conv * (d_in + 2 * N)     # depthwise convs
        if ctx <= 1:                              # decode recurrence
            f += 2 * nh * hd * N * 2             # state update + output
        else:                                     # chunked SSD
            f += 2 * Q * N                        # dots (C B^T) per token
            f += 2 * Q * nh * hd                  # M @ x per token
            f += 4 * nh * hd * N                  # state in/out terms
        f += 2 * d_in * d                         # out proj
    if cfg.d_ff:
        n_mat = 3 if cfg.act == "swiglu" else 2
        if cfg.layer_has_moe(i):
            m = cfg.moe
            f += 2 * d * m.num_experts                        # router
            f += n_mat * 2 * d * cfg.d_ff * m.top_k * moe_capacity
        else:
            f += n_mat * 2 * d * cfg.d_ff
    return f


def step_cost(cfg: ModelConfig, shape: InputShape, *,
              dp_size: int, fsdp: bool, window: int,
              remat_extra: float = 2.0, kv_bytes: int = 2) -> StepCost:
    B, S = shape.global_batch, shape.seq_len
    is_train = shape.kind == "train"
    tokens = B * (S if shape.kind != "decode" else 1)
    ctx = _attn_ctx(shape, window)

    layer_f = sum(_layer_flops_per_token(cfg, i, ctx)
                  for i in range(cfg.num_layers))
    # logits: every position for train, last position otherwise
    logit_tokens = tokens if is_train else B
    head_f = 2 * cfg.d_model * cfg.padded_vocab * logit_tokens
    fwd = layer_f * tokens + head_f

    params_total = cfg.param_count() * BF16
    if is_train:
        total = fwd * (3.0 + remat_extra)
        total += 12.0 * cfg.param_count()        # AdamW elementwise
    else:
        total = fwd

    # ---- HBM bytes (global) -------------------------------------------
    replicas = 1 if fsdp else dp_size
    passes = (3.0 + remat_extra) if is_train else 1.0
    param_traffic = params_total * replicas * passes
    opt_traffic = 0.0
    if is_train:
        # grads write/read + m/v read+write (state dtype ~ f32/bf16 ≈ 4B avg)
        opt_traffic = cfg.param_count() * (2 * BF16 + 4 * 4) * 1.0
    act_traffic = 6.0 * tokens * cfg.d_model * BF16 * cfg.num_layers
    kv_traffic = 0.0
    if shape.kind == "decode":
        attn_layers = sum(1 for i in range(cfg.num_layers)
                          if cfg.layer_kind(i) == "attn")
        # int8 KV: values at 1 byte + per-(pos, head) bf16 scales
        per_elem = kv_bytes + (2.0 / cfg.head_dim if kv_bytes == 1 else 0.0)
        kv_traffic = (B * ctx * attn_layers
                      * 2 * cfg.num_kv_heads * cfg.head_dim * per_elem)
        ssm_layers = cfg.num_layers - attn_layers
        if ssm_layers and cfg.ssm:
            st = (cfg.ssm.num_heads(cfg.d_model)
                  * cfg.ssm.head_dim * cfg.ssm.d_state)
            kv_traffic += B * ssm_layers * st * 4 * 2  # f32 read+write
    head_traffic = 2 * logit_tokens * cfg.padded_vocab * BF16 if is_train \
        else 0.0

    hbm = param_traffic + opt_traffic + act_traffic + kv_traffic + head_traffic
    return StepCost(
        fwd_flops=fwd, total_flops=total, hbm_bytes=hbm,
        detail=dict(layer_flops_per_token=layer_f, head_flops=head_f,
                    param_traffic=param_traffic, act_traffic=act_traffic,
                    kv_traffic=kv_traffic, opt_traffic=opt_traffic))
