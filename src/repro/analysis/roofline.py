"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory term     = HLO_bytes      / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the optimized HLO text: per-chip ring
traffic per op is

    all-reduce         2 x result bytes        (RS + AG phases)
    all-gather         1 x result bytes
    reduce-scatter     result bytes x group    (operand-sized send)
    all-to-all         1 x result bytes
    collective-permute 1 x result bytes

Collectives inside ``while`` bodies (the lax.scan over layer groups)
execute once per trip; the parser attributes a trip count to each
non-entry computation by matching the scan length (= num_groups), which
the caller passes in.  Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([\d,]*)\][^\s]*\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, loop_trip_count: int = 1) -> CollectiveStats:
    """Sum per-chip collective traffic.  Ops outside the ENTRY computation
    are assumed to sit in the layer-group scan body and are multiplied
    by ``loop_trip_count``."""
    bytes_by = {}
    count_by = {}
    # split into computations; the ENTRY one is marked
    chunks = re.split(r"\n(?=(?:ENTRY\s|%?\w[\w\.\-]*\s*\([^)]*\)\s*->))",
                      hlo_text)
    for chunk in chunks:
        is_entry = chunk.lstrip().startswith("ENTRY")
        mult = 1 if is_entry else loop_trip_count
        for m in _COLL_RE.finditer(chunk):
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            b = _shape_bytes(dtype, dims)
            if kind == "all-reduce":
                traffic = 2 * b
            elif kind == "reduce-scatter":
                gm = _GROUP_RE.search(chunk[m.start():m.start() + 2000])
                gsize = len(gm.group(1).split(",")) if gm else 2
                gm2 = _GROUP_V2_RE.search(chunk[m.start():m.start() + 2000])
                if gm2:
                    gsize = int(gm2.group(2))
                traffic = b * gsize
            else:
                traffic = b
            bytes_by[kind] = bytes_by.get(kind, 0.0) + traffic * mult
            count_by[kind] = count_by.get(kind, 0) + mult
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float          # per-chip traffic, summed over ops
    model_flops: float               # 6*N*D (or 6*N_active*D for MoE)
    collectives: Optional[CollectiveStats] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # collective_bytes is already per-chip ring traffic
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},{self.chips},"
                f"{self.t_compute:.6f},{self.t_memory:.6f},"
                f"{self.t_collective:.6f},{self.bottleneck},"
                f"{self.useful_flops_ratio:.3f}")

    HEADER = ("arch,shape,mesh,chips,t_compute_s,t_memory_s,"
              "t_collective_s,bottleneck,useful_flops_ratio")


def model_flops_for(cfg, shape, *, is_train: bool) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode processes
    one token per sequence; training includes the 2x backward (the 6x
    already counts fwd+bwd: 2ND fwd + 4ND bwd)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    per_tok = 6 * n if is_train else 2 * n
    return float(per_tok) * tokens
