"""Competitive-ratio analysis under a decode SLO (paper §III-B).

Implements, over *profiled* throughput curves (benchmarks/fig3 or the
simulator), every object in the paper's analysis:

  μ_P(R, t) = η_t μ_C(R) + (1-η_t) μ_R(R)                    (Eq. 1)
  r_min     = 1000 / τ_max                                    (Eq. 2)
  R*_g      = min{R ∈ G : μ_D(R) ≥ r_min}                     (Eq. 6)
  ρ_t       ≥ (1-ε̄) μ_P(S-R*_g-δ, t) / μ_P(S-R*_g, t)        (Thm. 1)
  ρ_t       ≥ (1-ε̄)(1 - L_P δ / μ_P(S-R*_g, t))              (Cor. 2)

plus a brute-force *offline optimum* (per-interval argmax over the slot
grid subject to the SLO) so the bound can be validated empirically:
benchmarks/competitive_ratio.py checks  ρ_measured ≥ ρ_bound.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass
class ThroughputProfile:
    """Profiled phase throughputs on the slot grid.

    levels: resource levels (monotone, e.g. [10, 20, ..., 100]);
    mu_*:   tokens/s at each level.  Monotonicity (Assumption 1) is
    enforced by isotonic projection at construction."""
    levels: np.ndarray
    mu_decode: np.ndarray
    mu_cold: np.ndarray
    mu_resume: np.ndarray

    def __post_init__(self):
        for name in ("mu_decode", "mu_cold", "mu_resume"):
            setattr(self, name, np.maximum.accumulate(
                np.asarray(getattr(self, name), dtype=float)))
        self.levels = np.asarray(self.levels)

    def mu_p(self, level: float, eta: float) -> float:
        """μ_P(R, t) with cold fraction η_t (Eq. 1), interpolated."""
        mc = np.interp(level, self.levels, self.mu_cold)
        mr = np.interp(level, self.levels, self.mu_resume)
        return eta * mc + (1.0 - eta) * mr

    def mu_d(self, level: float) -> float:
        return np.interp(level, self.levels, self.mu_decode)


def r_min_from_slo(tpot_slo_ms: float) -> float:
    """Eq. 2: decode steps/s needed to meet the TPOT SLO."""
    return 1000.0 / tpot_slo_ms


def r_star_g(profile: ThroughputProfile, r_min: float) -> int:
    """Eq. 6: smallest slot level whose decode throughput meets the SLO.
    Raises if the SLO is infeasible even at full allocation (Eq. 5)."""
    feasible = profile.levels[profile.mu_decode >= r_min]
    if len(feasible) == 0:
        raise ValueError(
            f"decode SLO infeasible: mu_D(S)={profile.mu_decode[-1]:.2f} "
            f"< r_min={r_min:.2f}")
    return int(feasible[0])


def instantaneous_bound(profile: ThroughputProfile, *, eta: float,
                        tpot_slo_ms: float, delta: float,
                        eps_bar: float) -> float:
    """Theorem 1 lower bound on ρ_t."""
    S = float(profile.levels[-1])
    rg = r_star_g(profile, r_min_from_slo(tpot_slo_ms))
    num = profile.mu_p(max(S - rg - delta, 0.0), eta)
    den = profile.mu_p(S - rg, eta)
    if den <= 0:
        return 1.0
    return (1.0 - eps_bar) * num / den


def linearized_bound(profile: ThroughputProfile, *, eta: float,
                     tpot_slo_ms: float, delta: float,
                     eps_bar: float) -> float:
    """Corollary 2, with L_P estimated as the max finite-difference slope
    of μ_P on [S - R*_g - δ, S - R*_g]."""
    S = float(profile.levels[-1])
    rg = r_star_g(profile, r_min_from_slo(tpot_slo_ms))
    lo, hi = max(S - rg - delta, 0.0), S - rg
    xs = np.linspace(lo, hi, 16)
    ys = np.array([profile.mu_p(x, eta) for x in xs])
    if len(xs) > 1 and xs[-1] > xs[0]:
        lp = float(np.max(np.abs(np.diff(ys) / np.diff(xs))))
    else:
        lp = 0.0
    den = profile.mu_p(hi, eta)
    if den <= 0:
        return 1.0 - eps_bar
    return (1.0 - eps_bar) * max(0.0, 1.0 - lp * delta / den)


def offline_optimum(profile: ThroughputProfile, etas: Sequence[float],
                    tpot_slo_ms: float, dt: float = 1.0) -> float:
    """π* (Eq. 3): per-interval best SLO-feasible prefill service.
    By Lemma 2 the per-interval optimum allocates exactly R*_g to decode."""
    rg = r_star_g(profile, r_min_from_slo(tpot_slo_ms))
    S = float(profile.levels[-1])
    return float(sum(profile.mu_p(S - rg, eta) * dt for eta in etas))


def achieved_service(profile: ThroughputProfile, etas: Sequence[float],
                     r_alloc: Sequence[float], eps_ctx: Sequence[float],
                     dt: float = 1.0) -> float:
    """Realized prefill service of a trace of (R_A(t), ε_ctx(t))."""
    S = float(profile.levels[-1])
    total = 0.0
    for eta, r, eps in zip(etas, r_alloc, eps_ctx):
        total += (1.0 - eps) * profile.mu_p(S - r, eta) * dt
    return float(total)
