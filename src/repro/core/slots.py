"""Pre-established discrete resource slots — the Green Context analogue.

Paper §III-C: ten CUDA Green Contexts reserving 10%..100% of SMs are
created *offline* because context construction is expensive; at runtime
threads are *rebound* to the nearest pre-created context ≥ the target
(<50 µs, vs milliseconds for construction).

TPU/JAX adaptation (DESIGN.md §2): the expensive offline operation is
**XLA compilation**; a "slot" is a pre-compiled executable for one point
on the discrete (decode_batch, prefill_chunk) step-shape grid, and
"rebinding" is dispatching to a different already-compiled executable.
The granularity invariant is identical: allocations are drawn from the
discrete set G = {g, 2g, ..., S} (Assumption 2), and the runtime rounds
a target reservation *up* to the nearest slot (bounded overshoot δ < g).

``SlotManager`` also measures both costs so the paper's claim structure
(construction >> rebind) can be validated on this substrate
(benchmarks/fig7_ablation.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class SlotStats:
    warmup_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    rebinds: int = 0
    rebind_total_s: float = 0.0
    misses: int = 0          # dispatches that had to compile on demand

    @property
    def mean_rebind_us(self) -> float:
        return 1e6 * self.rebind_total_s / max(self.rebinds, 1)


class SlotManager:
    """Discrete slot grid {g, 2g, ..., S} with pre-established executables.

    ``builder(level)`` returns the executable for a slot level (an int in
    units of g); with ``preestablish=False`` the manager degrades to the
    paper's "No-Green" ablation: every level change constructs on demand
    inside the serving path."""

    def __init__(self, total: int, granularity: int,
                 builder: Callable[[int], Any], *,
                 preestablish: bool = True):
        assert total % granularity == 0
        self.total = total
        self.g = granularity
        self.levels = [g for g in range(granularity, total + 1, granularity)]
        self._builder = builder
        self._slots: Dict[int, Any] = {}
        self.stats = SlotStats()
        self.current_level: Optional[int] = None
        if preestablish:
            self.warmup()

    # ---- offline construction (== Green Context creation) -------------
    def warmup(self) -> None:
        for lv in self.levels:
            t0 = time.perf_counter()
            self._slots[lv] = self._builder(lv)
            self.stats.warmup_s[lv] = time.perf_counter() - t0

    # ---- runtime rebinding (== cuGreenCtx switch) ----------------------
    def quantize_up(self, target: int) -> int:
        """Round a target reservation up to the nearest slot level.
        Overshoot δ is bounded by g - 1 (Assumption 2)."""
        target = max(min(target, self.total), self.g)
        return -(-target // self.g) * self.g

    def bind(self, target: int) -> Tuple[Any, int]:
        """Return (executable, level) for the nearest slot ≥ target."""
        lv = self.quantize_up(target)
        t0 = time.perf_counter()
        if lv not in self._slots:          # No-Green path: build on demand
            self._slots[lv] = self._builder(lv)
            self.stats.misses += 1
        exe = self._slots[lv]
        dt = time.perf_counter() - t0
        if self.current_level != lv:
            self.stats.rebinds += 1
            self.stats.rebind_total_s += dt
            self.current_level = lv
        return exe, lv

    def overshoot(self, target: int) -> int:
        """δ for a given target (slot-rounding overshoot)."""
        return self.quantize_up(target) - max(min(target, self.total), self.g)

    # ---- downward binding (decode-megastep grids) ----------------------
    def quantize_down(self, target: int) -> Optional[int]:
        """Round a target *down* to the nearest slot level, or ``None``
        when the target is below the smallest slot.  Used by grids whose
        level is a hard cap (e.g. megastep token counts must not exceed
        the shortest active decode burst), where rounding up would
        overshoot a correctness bound rather than a resource one."""
        if target < self.g:
            return None
        return min(target, self.total) // self.g * self.g

    def bind_down(self, target: int) -> Optional[Tuple[Any, int]]:
        """Return (executable, level) for the nearest slot ≤ target, or
        ``None`` when no level fits.  Same miss/rebind accounting as
        ``bind``."""
        lv = self.quantize_down(target)
        if lv is None:
            return None
        t0 = time.perf_counter()
        if lv not in self._slots:          # No-Green path: build on demand
            self._slots[lv] = self._builder(lv)
            self.stats.misses += 1
        exe = self._slots[lv]
        dt = time.perf_counter() - t0
        if self.current_level != lv:
            self.stats.rebinds += 1
            self.stats.rebind_total_s += dt
            self.current_level = lv
        return exe, lv
