"""AgentServe core: the paper's primary contribution.

Phase-aware classification (phases.py), TPOT-driven feedback scheduling
(scheduler.py, Algorithm 1), pre-established discrete resource slots
(slots.py, the CUDA Green Context analogue), dual-queue admission
(admission.py), the competitive-ratio analysis (competitive.py), and
the pure plan-based scheduling core (planner.py, DESIGN.md §9): one
``CyclePlanner`` per policy over an immutable ``EngineView``, consumed
identically by the real engine and the fluid simulator.
"""
from repro.core.phases import Phase, PhaseThresholds, classify  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    ControlState, SchedulerConfig, TPOTScheduler)
from repro.core.slots import SlotManager, SlotStats  # noqa: F401
from repro.core.admission import AdmissionQueues, Job  # noqa: F401
from repro.core.planner import (  # noqa: F401
    Admission, ColdOp, CyclePlan, CyclePlanner, CycleRecord, DecodePlan,
    EngineView, JobView, PlanJournal, PolicySpec, ReplayPlanner,
    ResumePlan, SessionView)
# (name -> planner resolution lives in repro.serving.policies.make_planner,
#  next to the named PolicySpec registry; core's make_planner is spec-only)
