"""AgentServe core: the paper's primary contribution.

Phase-aware classification (phases.py), TPOT-driven feedback scheduling
(scheduler.py, Algorithm 1), pre-established discrete resource slots
(slots.py, the CUDA Green Context analogue), dual-queue admission
(admission.py), and the competitive-ratio analysis (competitive.py).
"""
from repro.core.phases import Phase, PhaseThresholds, classify  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    ControlState, SchedulerConfig, TPOTScheduler)
from repro.core.slots import SlotManager, SlotStats  # noqa: F401
from repro.core.admission import AdmissionQueues, Job  # noqa: F401
