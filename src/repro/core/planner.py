"""Pure plan-based scheduling core (DESIGN.md §9).

The paper's claim is that *scheduling decisions alone* — phase split,
resume budgeting, adaptive partitions (Algorithm 1) — drive the serving
wins.  This module makes those decisions a first-class, swappable layer:
a ``CyclePlanner`` looks at an immutable ``EngineView`` (queues, session
phases, TPOT/control state, slot levels, KV pressure) and emits a
declarative ``CyclePlan`` — which control update to run, which slot
level to bind, which sessions to decode (and the megastep K), how to
compose the resume batch, which cold-prefill chunks to run, which
sessions to admit and how to route them, and (for the SLO-class
planner) which cold prefills to preempt.  Planners touch **no device
state**: they are pure functions of the view, unit-testable in
microseconds, and consumed verbatim by both the real engine
(``serving/engine.py`` executes plans through its ``Dispatcher``) and
the fluid simulator (``serving/simulator.py`` reads the same planner's
policy semantics) — one copy of every policy, no drift.

Plan → execute contract: ``ServingEngine.step()`` is

    ctrl = planner.plan_control(now, next_ctrl)   # control boundary?
    <execute ctrl: host-sync flush, Algorithm-1 update, clock advance>
    view = engine snapshot                        # post-control state
    plan = planner.plan(view)                     # everything else
    dispatcher.execute(plan)

The control decision is planned *before* the main view is built because
Algorithm 1's update rewrites the TPOT estimate and the partition that
every later decision (megastep K, slot level, chunk budgets) reads —
the view hands the planner the post-update numbers, exactly like the
pre-refactor inline loop.

Fidelity notes (vs the pre-refactor inlined engine): admissions are
planned from the post-control view, so on the rare control-boundary
cycle a resume is routed against the *updated* ``B_prefill`` (the old
code read the pre-update value); and an all-stale prefill queue no
longer triggers the opportunistic-reclaim slot bind.  Neither changes a
single emitted token — the golden-trace tests pin that.

Every executed plan is appended to the engine's ``PlanJournal``;
``ReplayPlanner`` feeds a recorded journal back through the dispatcher,
reproducing a run's token events deterministically (wall-clock
decisions — control timing, megastep sizing, admission readiness — are
all *inside* the recorded plans, so replay never consults the clock).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.phases import Phase, PhaseThresholds, classify

# Session lifecycle states, mirrored from serving.request.SessionState
# values (the core layer stays import-free of serving):
S_WAITING = "waiting_prefill"
S_PREFILLING = "prefilling"
S_DECODING = "decoding"
S_TOOL_CALL = "tool_call"
S_TOOL_WAIT = "tool_wait"
S_PAUSED = "prefill_paused"
S_FINISHED = "finished"

INTERACTIVE = "interactive"          # SLO classes (PriorityPlanner)
BATCH = "batch"
SLO_CLASSES = (INTERACTIVE, BATCH)


# ---------------------------------------------------------------------------
# the immutable view
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionView:
    """One session's scheduling-relevant state (no tokens, no tensors)."""
    session_id: int
    state: str                       # SessionState value
    slot: int
    turn_idx: int
    num_turns: int
    cached_len: int
    prefill_done: int
    turn_prefill_len: int            # len(current_turn.prefill_tokens)
    decode_len: int                  # current turn's decode burst length
    decoded: int
    shared_prefix_len: int
    ready_s: float
    slo: str = BATCH
    prefix_hit_len: int = 0          # non-mutating prefix-cache probe
    paused_seq: int = -1             # preemption order stamp (PAUSED only)
    deadline_s: float = float("inf")  # absolute SLO deadline (the engine
    #                                   aborts past it; planners may order
    #                                   admissions by urgency)

    @property
    def remaining_prefill(self) -> int:
        return self.turn_prefill_len - self.prefill_done

    @property
    def total_prompt_len(self) -> int:
        return self.cached_len + self.turn_prefill_len

    def aligned_remaining(self, prefill_done: Optional[int] = None,
                          cached_len: Optional[int] = None) -> int:
        """Remaining prefill capped at the shared-prefix boundary (so the
        prefix snapshot is taken at exactly that length); overridable
        counters let the prefill simulation advance a session."""
        done = self.prefill_done if prefill_done is None else prefill_done
        cached = self.cached_len if cached_len is None else cached_len
        rem = self.turn_prefill_len - done
        if (self.turn_idx == 0 and done < self.shared_prefix_len
                and cached < self.shared_prefix_len):
            rem = min(rem, self.shared_prefix_len - done)
        return rem


@dataclasses.dataclass(frozen=True)
class JobView:
    """One queued admission-queue entry."""
    session_id: int
    phase: Phase
    new_len: int


@dataclasses.dataclass(frozen=True)
class EngineView:
    """Immutable snapshot a planner sees — and nothing else."""
    now: float                       # engine clock at cycle start
    next_ctrl: float                 # next control boundary (post-advance)
    tpot_step_ms: float              # controller's TPOT estimate
    r_min: int                       # decode reservation (post-update)
    b_prefill: int                   # resume-prefill admission budget
    cycle_budget: int                # C
    granularity: int                 # g
    r_base: int                      # controller floor (reclaim binds here)
    max_seq: int
    free_slots: int
    slot_lengths: Tuple[int, ...]    # KV pool lengths per slot
    sessions: Tuple[SessionView, ...]        # registry insertion order
    q_decode: Tuple[JobView, ...]
    q_prefill: Tuple[JobView, ...]
    buckets: Tuple[int, ...]         # warmed resume token buckets
    resume_levels: Tuple[int, ...]   # warmed resume batch sizes M
    cold_levels: Tuple[int, ...]     # warmed cold-pack batch sizes
    megastep_levels: Tuple[int, ...] # warmed megastep K grid (() = none)
    chunk_tok_s: Mapping[int, float] = dataclasses.field(
        default_factory=dict)        # autotuned chunk -> tok/s (read-only)
    autotune: bool = True
    min_cached_fraction: float = 0.5
    resume_max_new: int = 1024

    def session(self, sid: int) -> SessionView:
        return self._by_id[sid]

    def __post_init__(self):
        object.__setattr__(self, "_by_id",
                           {s.session_id: s for s in self.sessions})


# ---------------------------------------------------------------------------
# the declarative plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ControlAction:
    """Control-boundary decision: host-sync the decode window (fresh
    TPOT) and optionally run the Algorithm-1 update."""
    flush: bool = False
    update: bool = False


@dataclasses.dataclass(frozen=True)
class Admission:
    """Admit one ready session and route its job."""
    session_id: int
    phase: Phase
    to_decode_queue: bool            # Q_D (in-budget resume) vs Q_P
    unpark: bool = False             # parked session: restore KV first
    restore_prefix: bool = False     # planner's peek saw a prefix hit
    #                                  (journal/debug — the dispatcher
    #                                  always probes at admission so the
    #                                  pool's hit/miss + LRU accounting
    #                                  happens exactly once)


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Dispatch one decode step over these sessions.  ``megastep_target``
    is the K the planner wants fused (0 = don't attempt a megastep; the
    dispatcher still clamps K to the live burst/capacity bounds)."""
    session_ids: Tuple[int, ...]
    megastep_target: int = 0


@dataclasses.dataclass(frozen=True)
class ResumePlan:
    """Batched resume-prefill composition: M sessions, one [M, bucket]
    executable (M is already rounded to a warmed batch size)."""
    session_ids: Tuple[int, ...]
    bucket: int


@dataclasses.dataclass(frozen=True)
class ColdOp:
    """One prefill-stream operation.

    kind: "whole" (run the session's prompt to completion — FCFS),
          "pack"  (M sessions into one [M, bucket] batched executable),
          "chunk" (``reps`` dispatches of a ``shape``-token chunk to one
                   session).
    fn_src: which warmed executable serves the chunk — "slot" (the
          cycle's bound slot executable), "slot_full" (the full-budget
          reclaim slot), "tuned" (autotune-table chunk executable), or
          "default" (the shared batch-1 prefill)."""
    kind: str
    session_ids: Tuple[int, ...]
    shape: int
    reps: int = 1
    fn_src: str = "default"
    reclaim: bool = False            # opportunistic full-budget pass


@dataclasses.dataclass(frozen=True)
class CyclePlan:
    """Everything one engine cycle will do, decided up front.

    ``plan_id`` is the telemetry/journal correlation key: the engine
    stamps it with the cycle index at execution time when it is still
    the -1 sentinel, and leaves recorded ids untouched — so a
    ``ReplayPlanner`` run re-executes plans under their *original* ids
    and its exported timeline can be diffed span-for-span against the
    source run's."""
    control: ControlAction = ControlAction()
    plan_id: int = -1                # stamped by the engine at execution
    slot_level: int = 0              # decode-reservation level to bind
    admissions: Tuple[Admission, ...] = ()
    preempt: Tuple[int, ...] = ()    # suspend these cold prefills
    unsuspend: Tuple[int, ...] = ()  # resume these suspended prefills
    decode: Optional[DecodePlan] = None
    flush_idle: bool = False         # no active decoders: sync the window
    resume: Optional[ResumePlan] = None
    prefill: Tuple[ColdOp, ...] = ()


# ---------------------------------------------------------------------------
# policy configuration (construction-time knobs + semantic defaults)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Per-policy configuration.

    The scheduling *semantics* live in the planner classes below; the
    spec carries their tunables plus the construction-time knobs the
    engine needs before any plan exists (which executable shapes to
    warm, whether slots are pre-established)."""
    name: str
    adaptive: bool = False            # run Algorithm 1 feedback
    split_phases: bool = False        # distinguish cold vs resume
    resume_to_decode_queue: bool = False  # fuse in-budget resumes into Q_D
    protect_decode: bool = True       # decode step every cycle
    chunk_by_slots: bool = False      # prefill chunk = slot partition share
    fixed_chunk_frac: float = 0.5     # when not slot-driven: share of budget
    whole_prefill: bool = False       # fcfs: run prefill to completion
    preestablish: bool = True         # pre-build slot executables
    static_r_frac: float = 0.5        # static decode reservation share


def quantize_up(target: int, total: int, g: int) -> int:
    """Round a reservation up to the slot grid (Assumption 2)."""
    target = max(min(target, total), g)
    return -(-target // g) * g


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def bucket_down(n: int, buckets: Sequence[int]) -> Optional[int]:
    best = None
    for b in buckets:
        if b <= n:
            best = b
    return best


# ---------------------------------------------------------------------------
# the planner strategy interface + shared machinery
# ---------------------------------------------------------------------------

class CyclePlanner:
    """Base planner: the dual-queue, slot-partitioned cycle shared by
    every policy.  Subclasses pin one policy each and override the
    decision hooks (`admits_resumes_to_decode`, `allow_decode`,
    `prefill_mode`, admission ordering, preemption).  Instances are
    stateless beyond their spec — ``plan`` is a pure function of the
    view."""

    def __init__(self, spec: PolicySpec):
        self.spec = spec

    # ---- identity ------------------------------------------------------
    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def adaptive(self) -> bool:
        return self.spec.adaptive

    # ---- run-start partition (non-adaptive policies) -------------------
    def static_r_min(self, total: int, g: int) -> Optional[int]:
        """Static decode reservation for non-adaptive policies (engine
        applies it once at run start), or None to leave the controller's
        initial point."""
        if self.adaptive:
            return None
        return max(g, int(self.spec.static_r_frac * total) // g * g)

    # ---- stage 1: the control boundary --------------------------------
    def plan_control(self, now: float, next_ctrl: float) -> ControlAction:
        due = now >= next_ctrl
        return ControlAction(flush=due, update=due and self.adaptive)

    # ---- stage 2: the cycle body --------------------------------------
    def plan(self, view: EngineView) -> CyclePlan:
        sim = _SimState(view)
        preempt = self.plan_preemptions(view, sim)
        admissions = self.plan_admissions(view, sim)
        slot_level = quantize_up(view.r_min, view.cycle_budget,
                                 view.granularity)
        decode, flush_idle = self.plan_decode(view, sim)
        resume = self.plan_resume(view, sim)
        prefill = self.plan_prefill(view, sim, slot_level)
        unsuspend = self.plan_unsuspend(view, sim)
        return CyclePlan(slot_level=slot_level, admissions=admissions,
                         preempt=preempt, unsuspend=unsuspend,
                         decode=decode, flush_idle=flush_idle,
                         resume=resume, prefill=prefill)

    # ---- admission -----------------------------------------------------
    def admission_order(self, candidates: List[SessionView],
                        ) -> List[SessionView]:
        """Service order for ready sessions (registry order by default)."""
        return candidates

    def classify_phase(self, sv: SessionView, cached: int,
                       new_len: int, view: EngineView) -> Phase:
        if not self.spec.split_phases:
            return Phase.COLD_PREFILL          # phase-blind baseline
        thr = PhaseThresholds(
            min_cached_fraction=view.min_cached_fraction,
            resume_max_new=view.resume_max_new)
        return classify(cached + sv.turn_prefill_len, cached, new_len, thr)

    def route_to_decode_queue(self, phase: Phase, new_len: int,
                              view: EngineView) -> bool:
        """Algorithm 1 lines 10-15: in-budget resumes join Q_D."""
        if not self.spec.resume_to_decode_queue:
            return False
        return (phase == Phase.RESUME_PREFILL
                and new_len <= view.b_prefill)

    def plan_admissions(self, view: EngineView, sim: "_SimState",
                        ) -> Tuple[Admission, ...]:
        ready = [sv for sv in view.sessions
                 if ((sv.state == S_WAITING or sv.state == S_TOOL_CALL)
                     and sv.ready_s <= view.now
                     and sv.deadline_s > view.now)]   # expired: engine
        #                                               aborts, not admits
        out: List[Admission] = []
        for sv in self.admission_order(ready):
            needs_slot = sv.state == S_WAITING or sv.slot < 0
            if needs_slot:
                if sim.free_slots == 0:
                    continue                   # backpressure: retry next cycle
                sim.free_slots -= 1
            restore = (sv.state == S_WAITING and sv.prefix_hit_len > 0)
            cached = sv.prefix_hit_len if restore else sv.cached_len
            done = sv.prefix_hit_len if restore else sv.prefill_done
            new_len = sv.turn_prefill_len - done
            phase = self.classify_phase(sv, cached, new_len, view)
            to_qd = self.route_to_decode_queue(phase, new_len, view)
            adm = Admission(session_id=sv.session_id, phase=phase,
                            to_decode_queue=to_qd,
                            unpark=sv.state == S_TOOL_CALL and sv.slot < 0,
                            restore_prefix=restore)
            out.append(adm)
            sim.admit(sv, adm, done, cached, new_len)
        return tuple(out)

    # ---- decode --------------------------------------------------------
    def allow_decode(self, view: EngineView, sim: "_SimState") -> bool:
        return self.spec.protect_decode or sim.q_p_len == 0

    def plan_decode(self, view: EngineView, sim: "_SimState",
                    ) -> Tuple[Optional[DecodePlan], bool]:
        active = [sv for sv in view.sessions if sv.state == S_DECODING]
        if not active:
            return None, True                  # sync any in-flight window
        if not self.allow_decode(view, sim):
            return None, False
        target = 0
        if (view.megastep_levels and sim.q_d_len == 0 and sim.q_p_len == 0):
            k_alive = min(sv.decode_len - sv.decoded for sv in active)
            k_cap = max(1, view.max_seq - 1
                        - max(view.slot_lengths[sv.slot] for sv in active))
            k_fit = k_alive
            tpot_s = view.tpot_step_ms / 1000.0
            if tpot_s > 0:
                k_fit = max(1, int((view.next_ctrl - view.now) / tpot_s))
            target = min(k_alive, k_cap, k_fit)
        return DecodePlan(
            session_ids=tuple(sv.session_id for sv in active),
            megastep_target=target), False

    # ---- batched resume prefills --------------------------------------
    def plan_resume(self, view: EngineView, sim: "_SimState",
                    ) -> Optional[ResumePlan]:
        if not self.spec.resume_to_decode_queue or not sim.q_d:
            return None
        eligible: List[SessionView] = []
        for job in sim.q_d:
            if len(eligible) >= view.resume_levels[-1]:
                break
            sv = sim.sv(job.session_id)
            if (sim.state(job.session_id) == S_PREFILLING
                    and sim.remaining(job.session_id) > 0):
                eligible.append(sv)
        if not eligible:
            return None
        m = max(lv for lv in view.resume_levels if lv <= len(eligible))
        chosen = eligible[:m]
        bucket = view.buckets[0]
        for sv in chosen:
            aligned = sim.aligned(sv.session_id)
            bucket = max(bucket, bucket_for(max(aligned, 1), view.buckets))
        for sv in chosen:
            # completions join the decode stream — the reclaim pass and
            # later plan stages must see them as decoding
            sim.apply_prefill(sv.session_id, bucket)
        return ResumePlan(
            session_ids=tuple(sv.session_id for sv in chosen),
            bucket=bucket)

    # ---- prefill stream ------------------------------------------------
    def prefill_mode(self, view: EngineView, slot_level: int):
        """(mode, budget) — "whole" | ("slot", C - level) | ("fixed", n)."""
        if self.spec.whole_prefill:
            return "whole", None
        if self.spec.chunk_by_slots:
            return "slot", view.cycle_budget - slot_level
        g = view.granularity
        c = int(self.spec.fixed_chunk_frac * view.cycle_budget)
        return "fixed", max(g, (c // g) * g)

    def tuned_chunk(self, view: EngineView, budget: int,
                    ) -> Tuple[int, int, bool]:
        """(chunk, reps, tuned): the measured-fastest warmed chunk ≤
        budget (>10% margin over the full budget — timing-noise guard),
        or (budget, 1, False) when autotune is off / nothing warmed."""
        table = view.chunk_tok_s
        if not view.autotune or not table:
            return budget, 1, False
        cands = [c for c in table if c <= budget]
        if not cands:
            return budget, 1, False
        full = max(cands)
        best = max(cands, key=lambda c: table[c])
        chunk = best if table[best] > 1.10 * table[full] else full
        reps = max(1, min(budget // chunk, 4))
        return chunk, reps, True

    def prefill_queue_order(self, jobs: List[JobView], sim: "_SimState",
                            ) -> List[JobView]:
        """Service order over the prefill stream (FIFO by default)."""
        return jobs

    # ---- fluid-simulator semantics (serving/simulator.py) --------------
    def sim_prefill_order(self, resumes: Sequence, colds: Sequence, *,
                          arrival, slo=None) -> List:
        """Service order over the fluid simulator's prefill backlog —
        the same policy semantics the engine planner applies through its
        queues: phase-split policies serve resumes first, phase-blind
        policies serve in arrival order.  ``arrival``/``slo`` are
        accessors over the caller's session objects."""
        if not self.spec.split_phases:
            return sorted(list(resumes) + list(colds), key=arrival)
        return list(resumes) + list(colds)

    def plan_prefill(self, view: EngineView, sim: "_SimState",
                     slot_level: int) -> Tuple[ColdOp, ...]:
        mode, budget = self.prefill_mode(view, slot_level)
        sim.q_p = self.prefill_queue_order(sim.q_p, sim)
        ops: List[ColdOp] = []
        if mode == "whole":
            op = self._sim_whole(view, sim)
            return (op,) if op else ()
        fn_src = "slot" if mode == "slot" else "default"
        op = self._sim_stream_op(view, sim, budget, fn_src)
        if op:
            ops.append(op)
        if (mode == "slot" and not sim.any_decoding_started
                and not any(sv.state == S_DECODING for sv in view.sessions)):
            # opportunistic reclaim (paper §III-C): no decode demand, the
            # prefill stream claims the full cycle budget
            full_budget = view.cycle_budget - quantize_up(
                view.r_base, view.cycle_budget, view.granularity)
            for _ in range(3):
                if not sim.q_p or sim.any_decoding_started:
                    break
                rop = self._sim_stream_op(view, sim, full_budget,
                                          "slot_full", reclaim=True)
                if rop is None:
                    break
                ops.append(rop)
        return tuple(ops)

    def plan_preemptions(self, view: EngineView, sim: "_SimState",
                         ) -> Tuple[int, ...]:
        return ()

    def plan_unsuspend(self, view: EngineView, sim: "_SimState",
                       ) -> Tuple[int, ...]:
        return ()

    # ---- prefill simulation helpers ------------------------------------
    def _sim_whole(self, view: EngineView, sim: "_SimState",
                   ) -> Optional[ColdOp]:
        sim.drop_stale_heads()
        if not sim.q_p:
            return None
        sid = sim.q_p[0].session_id
        sim.run_to_completion(sid)
        sim.q_p.pop(0)
        return ColdOp(kind="whole", session_ids=(sid,),
                      shape=view.buckets[-1])

    def _sim_stream_op(self, view: EngineView, sim: "_SimState",
                       budget: int, fn_src: str, reclaim: bool = False,
                       ) -> Optional[ColdOp]:
        sim.drop_stale_heads()
        if not sim.q_p or budget is None or budget <= 0:
            return None
        pack = self._sim_pack(view, sim, budget, reclaim)
        if pack is not None:
            return pack
        sid = sim.q_p[0].session_id
        chunk, reps, tuned = self.tuned_chunk(view, budget)
        done_reps = 0
        for _ in range(reps):
            if sim.state(sid) != S_PREFILLING:
                break
            sim.apply_prefill(sid, chunk)
            done_reps += 1
        if sim.state(sid) != S_PREFILLING:
            sim.q_p.pop(0)
        return ColdOp(kind="chunk", session_ids=(sid,), shape=chunk,
                      reps=reps, fn_src="tuned" if tuned else fn_src,
                      reclaim=reclaim)

    def _sim_pack(self, view: EngineView, sim: "_SimState", budget: int,
                  reclaim: bool) -> Optional[ColdOp]:
        """Mirror of the engine's cold-pack selection: the first M
        pending prefills into one [M, bucket] executable with bucket·M ≤
        the budget (stale entries scanned along the way are dropped)."""
        if not view.cold_levels:
            return None
        chosen: List[int] = []
        scan = 0
        while scan < len(sim.q_p) and len(chosen) < view.cold_levels[-1]:
            job = sim.q_p[scan]
            if sim.state(job.session_id) != S_PREFILLING:
                sim.q_p.pop(scan)              # stale: dropped by the scan
                continue
            chosen.append(job.session_id)
            scan += 1
        m = bucket = None
        if len(chosen) >= 2:
            for lv in reversed(view.cold_levels):
                if lv <= len(chosen):
                    b = bucket_down(budget // lv, view.buckets)
                    if b is not None:
                        need = max(sim.aligned(sid) for sid in chosen[:lv])
                        m = lv
                        bucket = min(b, bucket_for(need, view.buckets))
                        break
        if m is None:
            return None
        sids = chosen[:m]
        for sid in sids:
            sim.apply_prefill(sid, bucket)
        # queue update: the packed jobs leave their positions; unfinished
        # ones return to the head in order
        sid_set = set(sids)
        rest = [j for j in sim.q_p if j.session_id not in sid_set]
        back = [j for j in sim.q_p if j.session_id in sid_set
                and sim.state(j.session_id) == S_PREFILLING]
        sim.q_p = back + rest
        return ColdOp(kind="pack", session_ids=tuple(sids), shape=bucket,
                      fn_src="pack", reclaim=reclaim)


class _SimState:
    """Mutable cycle simulation the planner threads through its stages:
    queue contents and per-session prefill counters evolve exactly as
    the dispatcher will evolve them, so later plan stages see the state
    earlier stages produce.  Purely host arithmetic — no device state,
    no clocks."""

    def __init__(self, view: EngineView):
        self.view = view
        self.free_slots = view.free_slots
        self.q_d: List[JobView] = list(view.q_decode)
        self.q_p: List[JobView] = list(view.q_prefill)
        self._state: Dict[int, str] = {
            sv.session_id: sv.state for sv in view.sessions}
        self._done: Dict[int, int] = {
            sv.session_id: sv.prefill_done for sv in view.sessions}
        self._cached: Dict[int, int] = {
            sv.session_id: sv.cached_len for sv in view.sessions}
        self.any_decoding_started = False

    @property
    def q_d_len(self) -> int:
        return len(self.q_d)

    @property
    def q_p_len(self) -> int:
        return len(self.q_p)

    def sv(self, sid: int) -> SessionView:
        return self.view.session(sid)

    def state(self, sid: int) -> str:
        return self._state[sid]

    def remaining(self, sid: int) -> int:
        return self.sv(sid).turn_prefill_len - self._done[sid]

    def aligned(self, sid: int) -> int:
        return self.sv(sid).aligned_remaining(self._done[sid],
                                              self._cached[sid])

    def admit(self, sv: SessionView, adm: Admission, done: int,
              cached: int, new_len: int) -> None:
        self._state[sv.session_id] = S_PREFILLING
        self._done[sv.session_id] = done
        self._cached[sv.session_id] = cached
        job = JobView(session_id=sv.session_id, phase=adm.phase,
                      new_len=new_len)
        (self.q_d if adm.to_decode_queue else self.q_p).append(job)

    def suspend(self, sid: int) -> None:
        self._state[sid] = S_PAUSED
        self.q_p = [j for j in self.q_p if j.session_id != sid]
        self.free_slots += 1

    def apply_prefill(self, sid: int, shape: int) -> None:
        take = min(shape, self.aligned(sid))
        if take <= 0:
            return
        self._done[sid] += take
        self._cached[sid] += take
        if self.remaining(sid) == 0:
            self._state[sid] = S_DECODING
            self.any_decoding_started = True

    def run_to_completion(self, sid: int) -> None:
        while self.state(sid) == S_PREFILLING and self.remaining(sid) > 0:
            self.apply_prefill(sid, self.view.buckets[-1])
        self._state[sid] = S_DECODING
        self.any_decoding_started = True

    def drop_stale_heads(self) -> None:
        while self.q_p and self.state(self.q_p[0].session_id) \
                != S_PREFILLING:
            self.q_p.pop(0)


# ---------------------------------------------------------------------------
# one planner class per policy
# ---------------------------------------------------------------------------

class AgentServePlanner(CyclePlanner):
    """The paper's policy: phase split, in-budget resumes fused into the
    decode stream, cold prefills chunked by the adaptive slot partition,
    Algorithm-1 feedback, pre-established slots."""


class NoAlgPlanner(AgentServePlanner):
    """AgentServe minus Algorithm 1: the partition is frozen at the
    static point (§IV-D No-Alg ablation)."""


class NoGreenPlanner(AgentServePlanner):
    """AgentServe minus pre-established slots: identical plans; the
    engine constructs executables on demand inside the serving path (the
    cost the ablation measures)."""


class PDStaticPlanner(CyclePlanner):
    """SGLang-style PD disaggregation: decode protected behind a
    *static* partition; all prefills (cold and resume) share one FIFO
    prefill queue."""


class ChunkedPlanner(CyclePlanner):
    """vLLM-style chunked prefill + continuous batching: a fixed chunk
    budget mixed with decodes every cycle, phase-blind FIFO."""


class FCFSPlanner(CyclePlanner):
    """llama.cpp-style strict arrival order: a prefill runs to
    completion before any decode proceeds (the head-of-line-blocking
    baseline)."""


class PriorityPlanner(AgentServePlanner):
    """SLO-class scheduling: ``interactive`` sessions pre-empt ``batch``
    cold prefills.

    Extensions over AgentServe (all pure view logic):

    * admissions serve interactive-class sessions first;
    * when an interactive session is ready but the KV pool has no free
      slot, the batch-class cold prefill with the most remaining work is
      *suspended at a chunk boundary*: its KV rows stay resident on
      device through the existing park/unpark machinery, its slot is
      freed for the interactive request, and its queue entry is pulled;
    * the prefill stream serves interactive jobs ahead of batch jobs;
    * once no interactive demand is waiting and a slot is free, the
      oldest suspended prefill is resumed (unparked into a fresh slot,
      bit-identical state) and re-queued.
    """

    def admission_order(self, candidates: List[SessionView],
                        ) -> List[SessionView]:
        # interactive first; within a class, earliest deadline first
        # (stable: all-inf deadlines preserve registry order)
        return sorted(candidates,
                      key=lambda sv: (0 if sv.slo == INTERACTIVE else 1,
                                      sv.deadline_s))

    def prefill_queue_order(self, jobs: List[JobView], sim: "_SimState",
                            ) -> List[JobView]:
        return ([j for j in jobs if sim.sv(j.session_id).slo == INTERACTIVE]
                + [j for j in jobs
                   if sim.sv(j.session_id).slo != INTERACTIVE])

    def sim_prefill_order(self, resumes: Sequence, colds: Sequence, *,
                          arrival, slo=None) -> List:
        ordered = super().sim_prefill_order(resumes, colds,
                                            arrival=arrival, slo=slo)
        if slo is None:
            return ordered
        return ([s for s in ordered if slo(s) == INTERACTIVE]
                + [s for s in ordered if slo(s) != INTERACTIVE])

    def _interactive_demand(self, view: EngineView, sim: "_SimState",
                            ) -> int:
        """Interactive sessions ready now but needing a KV slot."""
        return sum(1 for sv in view.sessions
                   if sv.slo == INTERACTIVE and sv.ready_s <= view.now
                   and (sv.state == S_WAITING
                        or (sv.state == S_TOOL_CALL and sv.slot < 0)))

    def plan_preemptions(self, view: EngineView, sim: "_SimState",
                         ) -> Tuple[int, ...]:
        need = self._interactive_demand(view, sim) - sim.free_slots
        if need <= 0:
            return ()
        # cold-only invariant: an over-budget resume routed to Q_P keeps
        # its RESUME_PREFILL phase and is never a preemption victim
        cold_sids = {j.session_id for j in view.q_prefill
                     if j.phase == Phase.COLD_PREFILL}
        victims = sorted(
            (sv for sv in view.sessions
             if sv.slo != INTERACTIVE and sv.state == S_PREFILLING
             and sv.slot >= 0 and sv.session_id in cold_sids
             and sv.remaining_prefill > 0),
            key=lambda sv: -sv.remaining_prefill)
        out = []
        for sv in victims[:need]:
            out.append(sv.session_id)
            sim.suspend(sv.session_id)
        return tuple(out)

    def plan_unsuspend(self, view: EngineView, sim: "_SimState",
                       ) -> Tuple[int, ...]:
        if sim.free_slots <= 0 or self._interactive_demand(view, sim) > 0:
            return ()
        paused = [sv for sv in view.sessions
                  if sim.state(sv.session_id) == S_PAUSED]
        if not paused:
            return ()
        sv = min(paused, key=lambda v: v.paused_seq)  # oldest suspension
        sim.free_slots -= 1
        return (sv.session_id,)


# ---------------------------------------------------------------------------
# journal + deterministic replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CycleRecord:
    """One executed cycle: the plan plus its observable outcome."""
    cycle: int
    plan: CyclePlan
    events: int = 0                  # token events this cycle emitted
    did_work: bool = False


@dataclasses.dataclass
class PlanJournal:
    """Record of every executed ``CyclePlan`` (bounded).  Feed it to a
    ``ReplayPlanner`` to re-execute a run deterministically, or to
    ``summary()`` for per-policy reporting."""
    max_records: int = 200_000
    records: List[CycleRecord] = dataclasses.field(default_factory=list)
    dropped: int = 0

    def record(self, rec: CycleRecord) -> None:
        if len(self.records) < self.max_records:
            self.records.append(rec)
        else:
            self.dropped += 1

    def summary(self) -> Dict[str, float]:
        chunks: List[int] = []
        preemptions = resumes = admissions = packs = megasteps = 0
        decode_cycles = resume_batches = 0
        for r in self.records:
            p = r.plan
            preemptions += len(p.preempt)
            resumes += len(p.unsuspend)
            admissions += len(p.admissions)
            if p.decode is not None:
                decode_cycles += 1
                if p.decode.megastep_target > 1:
                    megasteps += 1
            if p.resume is not None:
                resume_batches += 1
            for op in p.prefill:
                if op.kind == "pack":
                    packs += 1
                    chunks.extend([op.shape] * len(op.session_ids))
                elif op.kind == "chunk":
                    chunks.extend([op.shape] * op.reps)
        return dict(
            cycles=float(len(self.records)),
            dropped=float(self.dropped),
            admissions=float(admissions),
            decode_cycles=float(decode_cycles),
            megastep_cycles=float(megasteps),
            resume_batches=float(resume_batches),
            cold_packs=float(packs),
            preemptions=float(preemptions),
            preempt_resumes=float(resumes),
            mean_chunk=float(sum(chunks) / len(chunks)) if chunks else 0.0)


class ReplayPlanner:
    """Plays a recorded journal back through the dispatcher.

    Every wall-clock-dependent decision (control boundaries, megastep
    sizing, admission readiness) is inside the recorded plans, and the
    dispatcher never consults the clock for correctness, so replaying a
    journal against the same attached workload reproduces the original
    run's token events exactly — the golden-trace debugging loop."""

    def __init__(self, journal: PlanJournal,
                 spec: Optional[PolicySpec] = None):
        self._records = journal.records
        self._i = -1
        self.spec = spec or PolicySpec(name="replay")

    @property
    def name(self) -> str:
        return f"replay:{self.spec.name}"

    @property
    def adaptive(self) -> bool:
        return False

    def static_r_min(self, total: int, g: int) -> Optional[int]:
        return None                   # partition comes from recorded plans

    def exhausted(self) -> bool:
        return self._i + 1 >= len(self._records)

    def plan_control(self, now: float, next_ctrl: float) -> ControlAction:
        self._i += 1
        if self._i >= len(self._records):
            raise RuntimeError(
                f"replay journal exhausted after {len(self._records)} "
                f"cycles — the run diverged from the recording")
        return self._records[self._i].plan.control

    def plan(self, view: EngineView) -> CyclePlan:
        return self._records[self._i].plan


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

PLANNER_CLASSES: Dict[str, type] = {
    "agentserve": AgentServePlanner,
    "pd_static": PDStaticPlanner,
    "chunked": ChunkedPlanner,
    "fcfs": FCFSPlanner,
    "no_alg": NoAlgPlanner,
    "no_green": NoGreenPlanner,
    "priority": PriorityPlanner,
}


def make_planner(spec: PolicySpec) -> CyclePlanner:
    """Planner for a spec: by registered name, else inferred from the
    spec's shape (custom specs, e.g. fig7's static-partition sweeps).
    Spec-only by design — resolving policy *names* needs the named-spec
    registry, which lives in ``repro.serving.policies.make_planner``."""
    if not isinstance(spec, PolicySpec):
        raise TypeError(
            f"expected a PolicySpec, got {spec!r}; to resolve a policy "
            f"name use repro.serving.policies.make_planner")
    cls = PLANNER_CLASSES.get(spec.name)
    if cls is None:
        if spec.whole_prefill:
            cls = FCFSPlanner
        elif not spec.chunk_by_slots:
            cls = ChunkedPlanner
        elif spec.resume_to_decode_queue:
            cls = AgentServePlanner if spec.adaptive else NoAlgPlanner
        else:
            cls = PDStaticPlanner
    return cls(spec)
