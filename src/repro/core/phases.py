"""Phase-aware request taxonomy (paper §II, Fig 1).

Agent traffic decomposes into three phases with very different resource
profiles:

* ``COLD_PREFILL``   — long uncached system prompt (2.5k-3.5k tokens);
                       compute-heavy, the head-of-line-blocking source.
* ``RESUME_PREFILL`` — tool output / steering text appended to a cached
                       context (30-421 tokens); short, frequent.
* ``DECODE``         — structured-output generation (27-141 tokens);
                       lightweight per token, latency-critical.

``classify`` implements the Request Manager's decision (paper §III-A):
a request whose prefix is cached beyond a threshold fraction is a resume
prefill; otherwise it is cold.  Decode is a state, not an arrival — a
sequence enters DECODE after its prefill completes.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Phase(enum.Enum):
    COLD_PREFILL = "cold_prefill"
    RESUME_PREFILL = "resume_prefill"
    DECODE = "decode"


@dataclasses.dataclass
class PhaseThresholds:
    """Classification knobs.

    ``min_cached_fraction``: how much of the request's prefix must be
    KV-cached for it to count as a resume (cache-extension) prefill.
    ``resume_max_new``: resume prefills longer than this are *re-routed
    to the cold queue* regardless of cache state (paper §III-A: "unless
    they exceed a predefined token budget")."""
    min_cached_fraction: float = 0.5
    resume_max_new: int = 1024


def classify(total_len: int, cached_len: int, new_len: int,
             thresholds: Optional[PhaseThresholds] = None) -> Phase:
    """Classify an incoming *prefill* request.

    total_len: prompt length including cached prefix; cached_len: tokens
    already in the KV cache for this session; new_len: tokens that still
    need prefilling (total_len - cached_len)."""
    t = thresholds or PhaseThresholds()
    if new_len <= 0:
        return Phase.DECODE
    if cached_len > 0 and cached_len / max(total_len, 1) >= t.min_cached_fraction \
            and new_len <= t.resume_max_new:
        return Phase.RESUME_PREFILL
    return Phase.COLD_PREFILL
