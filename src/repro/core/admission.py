"""Dual-queue request admission (paper §III-A, Orchestration Layer).

Q_D holds decode jobs plus resume prefills within the current budget
B_prefill(t); Q_P holds cold prefills and over-budget resume prefills.
Cold prefills never enter Q_D — that is the isolation invariant the
property tests assert.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

from repro.core.phases import Phase
from repro.core.scheduler import TPOTScheduler


@dataclasses.dataclass
class Job:
    """One schedulable unit of work."""
    session_id: int
    phase: Phase
    new_len: int                 # tokens to prefill (0 for decode jobs)
    arrival_s: float = 0.0
    enqueued_cold: bool = False  # set if a resume was re-routed to Q_P


class AdmissionQueues:
    def __init__(self, scheduler: TPOTScheduler):
        self.scheduler = scheduler
        self.q_decode: Deque[Job] = collections.deque()   # Q_D
        self.q_prefill: Deque[Job] = collections.deque()  # Q_P

    def enqueue(self, job: Job) -> str:
        """Algorithm 1 lines 10-15. Returns which queue the job entered."""
        if job.phase == Phase.DECODE:
            self.q_decode.append(job)
            return "Q_D"
        if (job.phase == Phase.RESUME_PREFILL
                and self.scheduler.admit_to_decode_queue(False, job.new_len)):
            self.q_decode.append(job)
            return "Q_D"
        job.enqueued_cold = job.phase == Phase.RESUME_PREFILL
        self.q_prefill.append(job)
        return "Q_P"

    def pop_decode_batch(self, max_jobs: int) -> List[Job]:
        out = []
        while self.q_decode and len(out) < max_jobs:
            out.append(self.q_decode.popleft())
        return out

    def pop_prefill(self) -> Optional[Job]:
        return self.q_prefill.popleft() if self.q_prefill else None

    def occupancy(self):
        return len(self.q_decode), len(self.q_prefill)

    def total_occupancy(self) -> int:
        return len(self.q_decode) + len(self.q_prefill)


@dataclasses.dataclass
class WatermarkGate:
    """Hysteretic admission gate for the online gateway (DESIGN.md §6).

    Open-loop arrivals are unbounded, so the gateway sheds load instead
    of queueing forever: when occupancy (queued jobs + sessions waiting
    for a KV slot) reaches ``high`` the gate closes and submissions are
    rejected (surfaced as 429-style results); it reopens only once
    occupancy drains to ``low``.  The high/low hysteresis prevents
    reject/accept flapping right at the boundary.

    ``pressure`` tightens the gate without reconfiguring it: the
    effective high watermark drops by that amount (floored just above
    ``low`` so the hysteresis invariant holds).  The gateway raises it
    while the engine reports KV-exhaustion deferrals — shedding at the
    door is the cheapest rung of the degradation ladder (DESIGN.md
    §10) — and clears it once the pressure passes."""
    high: int
    low: int = -1                    # default: high // 2
    shedding: bool = False
    admitted: int = 0
    rejected: int = 0
    pressure: int = 0                # transient tightening (KV pressure)

    def __post_init__(self):
        if self.low < 0:
            self.low = self.high // 2
        if self.low >= self.high:
            raise ValueError(f"low watermark {self.low} must be below "
                             f"high {self.high}")

    def effective_high(self) -> int:
        return max(self.low + 1, self.high - self.pressure)

    def set_pressure(self, pressure: int) -> None:
        self.pressure = max(0, int(pressure))

    def check(self, occupancy: int) -> bool:
        """Update the shedding state for the observed occupancy and
        return whether a request would be admitted (no counting)."""
        if occupancy >= self.effective_high():
            self.shedding = True
        elif occupancy <= self.low:
            self.shedding = False
        return not self.shedding

    def offer(self, occupancy: int) -> bool:
        """check() plus admitted/rejected accounting — call once per
        actual submission decision."""
        ok = self.check(occupancy)
        if ok:
            self.admitted += 1
        else:
            self.rejected += 1
        return ok
