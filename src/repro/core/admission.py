"""Dual-queue request admission (paper §III-A, Orchestration Layer).

Q_D holds decode jobs plus resume prefills within the current budget
B_prefill(t); Q_P holds cold prefills and over-budget resume prefills.
Cold prefills never enter Q_D — that is the isolation invariant the
property tests assert.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

from repro.core.phases import Phase
from repro.core.scheduler import TPOTScheduler


@dataclasses.dataclass
class Job:
    """One schedulable unit of work."""
    session_id: int
    phase: Phase
    new_len: int                 # tokens to prefill (0 for decode jobs)
    arrival_s: float = 0.0
    enqueued_cold: bool = False  # set if a resume was re-routed to Q_P


class AdmissionQueues:
    def __init__(self, scheduler: TPOTScheduler):
        self.scheduler = scheduler
        self.q_decode: Deque[Job] = collections.deque()   # Q_D
        self.q_prefill: Deque[Job] = collections.deque()  # Q_P

    def enqueue(self, job: Job) -> str:
        """Algorithm 1 lines 10-15. Returns which queue the job entered."""
        if job.phase == Phase.DECODE:
            self.q_decode.append(job)
            return "Q_D"
        if (job.phase == Phase.RESUME_PREFILL
                and self.scheduler.admit_to_decode_queue(False, job.new_len)):
            self.q_decode.append(job)
            return "Q_D"
        job.enqueued_cold = job.phase == Phase.RESUME_PREFILL
        self.q_prefill.append(job)
        return "Q_P"

    def pop_decode_batch(self, max_jobs: int) -> List[Job]:
        out = []
        while self.q_decode and len(out) < max_jobs:
            out.append(self.q_decode.popleft())
        return out

    def pop_prefill(self) -> Optional[Job]:
        return self.q_prefill.popleft() if self.q_prefill else None

    def occupancy(self):
        return len(self.q_decode), len(self.q_prefill)
