"""TPOT-driven resource scheduling — Algorithm 1, faithfully.

The controller regulates two variables each control interval Δt:

* ``B_prefill(t)`` — the resume-prefill token budget: the maximum resume
  prefill length admitted into the decode queue/stream.
* ``R_min(t)``     — the minimum resource reservation for decode.  On
  GPU this is SMs; in the TPU/JAX adaptation it is the decode share of
  the per-step token budget, quantised to the pre-established slot grid
  (DESIGN.md §2).

Control law (paper Algorithm 1, lines 4-9):

    TPOT_step = ΔL_decode / ΔK_decode
    if TPOT_step > θ_high:   B -= Δ_B (floor B_min);  R += Δ_R (cap S)
    elif TPOT_step < θ_low:  B += Δ_B (cap B_max);    R -= Δ_R (floor R_base)

The scheduler is deliberately mechanism-agnostic: it emits integer
resource units in [0, S]; the execution layer (slots.py / engine.py)
decides what a unit means.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass
class SchedulerConfig:
    total_resources: int = 100      # S: total resource units on the device
    r_base: int = 10                # floor of the decode reservation
    r_init: int = 30
    delta_r: int = 10               # Δ_R: reservation step (= slot granularity g)
    b_min: int = 16                 # resume budget floor (tokens)
    b_max: int = 1024               # resume budget cap
    b_init: int = 256
    delta_b: int = 64               # Δ_B: budget step
    theta_low_ms: float = 0.0       # θ_low; 0 => derive from SLO
    theta_high_ms: float = 0.0      # θ_high; 0 => derive from SLO
    tpot_slo_ms: float = 50.0       # τ_max for deriving thresholds
    control_interval_s: float = 0.25  # Δt

    def __post_init__(self):
        if self.theta_high_ms <= 0:
            self.theta_high_ms = 0.9 * self.tpot_slo_ms
        if self.theta_low_ms <= 0:
            self.theta_low_ms = 0.5 * self.tpot_slo_ms


@dataclasses.dataclass
class ControlState:
    b_prefill: int
    r_min: int
    tpot_step_ms: float = 0.0
    mode: str = "hold"              # protect | relax | hold


class TPOTScheduler:
    """Feedback controller over (B_prefill, R_min). One instance per engine."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.state = ControlState(b_prefill=cfg.b_init, r_min=cfg.r_init)
        # interval accumulators (ΔL_decode, ΔK_decode)
        self._decode_time_s = 0.0
        self._decode_steps = 0
        self.history: List[ControlState] = []

    # ---- measurement (Algorithm 1 lines 2-3) --------------------------
    def record_decode_step(self, elapsed_s: float, steps: int = 1) -> None:
        self._decode_time_s += elapsed_s
        self._decode_steps += steps

    # ---- control update (Algorithm 1 lines 4-9) -----------------------
    def update(self) -> ControlState:
        c, s = self.cfg, self.state
        if self._decode_steps > 0:
            tpot_ms = 1000.0 * self._decode_time_s / self._decode_steps
            s.tpot_step_ms = tpot_ms
            if tpot_ms > c.theta_high_ms:           # protection mode
                s.b_prefill = max(c.b_min, s.b_prefill - c.delta_b)
                s.r_min = min(c.total_resources, s.r_min + c.delta_r)
                s.mode = "protect"
            elif tpot_ms < c.theta_low_ms:          # relaxation mode
                s.b_prefill = min(c.b_max, s.b_prefill + c.delta_b)
                s.r_min = max(c.r_base, s.r_min - c.delta_r)
                s.mode = "relax"
            else:
                s.mode = "hold"
        self._decode_time_s = 0.0
        self._decode_steps = 0
        self.history.append(dataclasses.replace(s))
        return s

    # ---- partition (Algorithm 1 line 16) ------------------------------
    def partition(self) -> Tuple[int, int]:
        """(S_decode, S_prefill) = (R_min, S - R_min)."""
        return self.state.r_min, self.cfg.total_resources - self.state.r_min

    # ---- admission test (Algorithm 1 lines 10-15) ----------------------
    def admit_to_decode_queue(self, is_decode: bool, new_len: int) -> bool:
        return is_decode or new_len <= self.state.b_prefill
