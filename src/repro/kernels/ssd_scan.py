"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (intra-chunk +
state carry), the compute hot-spot of the SSM / hybrid architectures.

Grid: (batch, heads, chunks) with chunks innermost (sequential on TPU),
so the [hd, N] recurrent state for one (b, h) lives in VMEM scratch
across the whole sequence — the inter-chunk recurrence never leaves
VMEM.  Within a chunk the quadratic "dual form" runs on the MXU:
three [Q, Q] / [Q, hd] / [Q, N] matmuls with Q = chunk_size (default
128/256, MXU-aligned).

B/C projections are shared across heads (ngroups=1, as in mamba2-780m):
their BlockSpecs ignore the head grid index, so each [Q, N] tile is
fetched once per head from the same HBM buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, dta_ref, b_ref, c_ref, h0_ref,
                y_ref, hout_ref, h_scr, *, num_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, hd]
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)  # [Q]
    dta = dta_ref[0, 0, :, 0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)            # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)            # [Q, N]

    s = jnp.cumsum(dta)                          # [Q]
    # intra-chunk quadratic (dual/attention-like) term
    dots = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(s[:, None] - s[None, :]), 0.0)
    M = dots * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)     # [Q, hd]
    # carried-in state contribution: C_i . H_in * exp(s_i)
    h_in = h_scr[...]                                               # [hd, N]
    y += jnp.exp(s)[:, None] * jax.lax.dot_general(
        Cm, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    # end-of-chunk state
    w = jnp.exp(s[-1] - s) * dt                                     # [Q]
    h_new = h_in * jnp.exp(s[-1]) + jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                         # [hd, N]
    h_scr[...] = h_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _finalize():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan_bhsd(
    x, dt, dtA, Bm, Cm, h0, *, chunk: int = 128, interpret: bool = False,
):
    """Chunked SSD scan.

    x:   [B, nh, S, hd]      per-head inputs (post-conv, f32/bf16)
    dt:  [B, nh, S]          post-softplus step sizes
    dtA: [B, nh, S]          dt * A  (A negative)
    Bm:  [B, S, N]           input projection (shared across heads)
    Cm:  [B, S, N]           output projection (shared across heads)
    h0:  [B, nh, hd, N]      carried-in state
    Returns (y [B, nh, S, hd], h_final [B, nh, hd, N]).  S % chunk == 0.
    """
    B, nh, S, hd = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    dt4 = dt[..., None]   # [B, nh, S, 1]
    dta4 = dtA[..., None]

    kernel = functools.partial(_ssd_kernel, num_chunks=nc, chunk=chunk)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, S, hd), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt4, dta4, Bm, Cm, h0)
    return y, h_final
