"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately naive (full score matrices, step-by-step
recurrences) — they are the ground truth the kernels and the blocked XLA
paths are tested against, never the execution path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=None,
                    lengths=None):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, Hk, hd] -> [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32)
    if lengths is None:
        lengths = jnp.full((B,), Sk, jnp.int32)
    kr = jnp.repeat(k, G, axis=2)  # [B, Sk, H, hd]
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (hd ** 0.5)
    q_pos = q_offset[:, None] + jnp.arange(Sq)[None, :]          # [B, Sq]
    k_pos = jnp.arange(Sk)[None, :]                               # [1, Sk]
    valid = k_pos[:, None, :] < lengths[:, None, None]            # [B,Sq,Sk]
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])
        if window > 0:
            valid = valid & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(valid[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def naive_decode_attention(q, k_cache, v_cache, lengths, *, window=0):
    """q: [B, 1, H, hd]; caches [B, S, Hk, hd]; lengths incl. current."""
    return naive_attention(q, k_cache, v_cache, causal=True, window=window,
                           q_offset=lengths - 1, lengths=lengths)


def naive_ssd(x, dt, Bm, Cm, A, D, h0=None):
    """Step-by-step SSD recurrence (the definition, O(S) sequential).

    x: [B, S, nh, hd]; dt: [B, S, nh] (post-softplus); Bm/Cm: [B, S, N];
    A: [nh] (negative); D: [nh].  Returns (y [B,S,nh,hd], h_final)."""
    B_, S, nh, hd = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B_, nh, hd, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # [B,nh,hd], [B,nh], [B,N], [B,N]
        a = jnp.exp(dt_t * A[None, :])                       # [B, nh]
        h = h * a[:, :, None, None] + jnp.einsum(
            "bh,bhd,bn->bhdn", dt_t, x_t, b_t)
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
          Bm.swapaxes(0, 1).astype(jnp.float32),
          Cm.swapaxes(0, 1).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h


def naive_gmm(x, w):
    """Grouped expert matmul oracle: [E,C,d] x [E,d,f] -> [E,C,f]."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
