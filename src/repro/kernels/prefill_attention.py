"""Pallas TPU cache-aware prefill attention: a [B, Sq] query chunk vs
the resident KV cache [B, S_max].

Serving prefills (cold chunks and resumes) attend a short query chunk
against a cache whose *padded* extent S_max is far larger than the
tokens actually written.  The XLA ``blocked_attention`` scan streams all
S_max tiles per chunk regardless; this kernel makes the streamed bytes
O(actual length) instead, the prefill analogue of the decode kernel's
revisit-block trick (``decode_attention.py``):

* ``q_offset``/``lengths`` arrive via scalar prefetch
  (``PrefetchScalarGridSpec``) so they are available to the BlockSpec
  index maps *before* the tile loop.
* For query tile ``iq`` of row ``b`` the live KV range is
  ``(first, last]`` in tile units, where ``last`` is bounded by both
  causality (no key beyond ``q_offset + (iq+1)·block_q``) and the valid
  length (no key beyond ``lengths[b]`` was ever written), and ``first``
  prunes tiles wholly below the sliding window.
* Tiles outside ``[first, last]`` map back to the ``last`` in-range tile
  index; the Pallas pipeline elides the HBM->VMEM DMA when a block index
  repeats across consecutive grid steps, and a ``pl.when`` guard skips
  their compute.

GQA is expressed in the index maps (query head ``h`` fetches kv head
``h // group``) so KV tiles are fetched once per kv-head group.  The
quantised-KV variant streams int8 values + per-position scales and
dequantises per tile in VMEM — half the cache bytes, same pruning.

Every query row must have >= 1 unmasked key (``q_offset + i <
lengths``), which the serving path guarantees (``lengths`` counts the
chunk itself); all-masked rows would reduce over an implementation-
defined tile subset.  ``interpret=True`` validates the kernel body on
CPU (no DMA elision there — parity only; CPU perf claims use the
pruned-extent reference in ``benchmarks/prefill.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tile_bounds(qoff_ref, len_ref, b, iq, *, block_q: int, block_k: int,
                 causal: bool, window: int):
    """(first, last) inclusive physical KV-tile bounds for query tile
    ``iq`` of batch row ``b``.  Shared verbatim by the BlockSpec index
    maps and the kernel-body compute guard — the pruning invariant is
    that both always agree."""
    q_lo = qoff_ref[b] + iq * block_q
    limit = len_ref[b]
    if causal:
        limit = jnp.minimum(limit, q_lo + block_q)   # keys <= q_hi
    last = jnp.maximum((limit + block_k - 1) // block_k, 1) - 1
    if causal and window > 0:
        first = jnp.maximum(q_lo - window + 1, 0) // block_k
        first = jnp.minimum(first, last)
    else:
        first = jnp.zeros_like(last)
    return first, last


def _softmax_tile(q_scaled, k, v, mask, m_scr, l_scr, acc_scr):
    """One online-softmax accumulation step over a [bq, bk] score tile."""
    s = jax.lax.dot_general(
        q_scaled, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # [bq, bk]
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[:, 0] = l_scr[:, 0] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new


def _kernel_common(qoff_ref, len_ref, q_ref, load_kv, o_ref,
                   m_scr, l_scr, acc_scr, *, causal: bool, window: int,
                   scale: float, block_q: int, block_k: int,
                   num_kv_blocks: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first, last = _tile_bounds(qoff_ref, len_ref, b, iq, block_q=block_q,
                               block_k=block_k, causal=causal, window=window)

    @pl.when(first + ik <= last)
    def _compute():
        # the tile actually resident in VMEM (same remap as the index map)
        k_start = jnp.minimum(first + ik, last) * block_k
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, hd]
        k, v = load_kv()
        q_pos = (qoff_ref[b] + iq * block_q
                 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < len_ref[b]
        if causal:
            mask = mask & (k_pos <= q_pos)
            if window > 0:
                mask = mask & (k_pos > q_pos - window)
        _softmax_tile(q, k, v, mask, m_scr, l_scr, acc_scr)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _prefill_kernel(qoff_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                    m_scr, l_scr, acc_scr, **kw):
    def load_kv():
        return (k_ref[0, 0].astype(jnp.float32),
                v_ref[0, 0].astype(jnp.float32))
    _kernel_common(qoff_ref, len_ref, q_ref, load_kv, o_ref,
                   m_scr, l_scr, acc_scr, **kw)


def _prefill_kernel_quant(qoff_ref, len_ref, q_ref, k_ref, ks_ref,
                          v_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr, **kw):
    def load_kv():
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)
        return k, v
    _kernel_common(qoff_ref, len_ref, q_ref, load_kv, o_ref,
                   m_scr, l_scr, acc_scr, **kw)


def _prefill_kernel_paged(qoff_ref, len_ref, bt_ref, *args, **kw):
    """Paged variant: the block table only feeds the BlockSpec index
    maps — the body is layout-blind (tile positions are logical)."""
    _prefill_kernel(qoff_ref, len_ref, *args, **kw)


def _prefill_kernel_paged_quant(qoff_ref, len_ref, bt_ref, *args, **kw):
    _prefill_kernel_quant(qoff_ref, len_ref, *args, **kw)


def _build(q, kv_leaves, q_offset, lengths, kernel, *, causal: bool,
           window: int, block_q: int, block_k: int, interpret: bool,
           block_tables=None):
    """Shared pallas_call assembly for the plain and quantised variants.

    Slab layout (``block_tables=None``): kv_leaves are
    [B, Hk, Sk, lastdim] and tile ``ik`` fetches cache rows
    ``ik*block_k``.  Paged layout: kv_leaves are page arenas
    [Hk, P_phys, page, lastdim] with ``block_k`` = the page size, and
    the k-tile grid index maps through the scalar-prefetched block
    table — logical tile ``lt`` fetches physical page ``bt[b, lt]``.
    Both layouts share the same pruning bounds and kernel body (tile
    positions are logical either way)."""
    B, H, Sq, hd = q.shape
    paged = block_tables is not None
    if paged:
        Hk, ps = kv_leaves[0].shape[0], kv_leaves[0].shape[2]
        assert ps == block_k, (ps, block_k)
        nk = block_tables.shape[1]
    else:
        Hk, Sk = kv_leaves[0].shape[1], kv_leaves[0].shape[2]
        assert Sk % block_k == 0
        nk = Sk // block_k
    group = H // Hk
    assert Sq % block_q == 0
    nq = Sq // block_q
    scale = 1.0 / (hd ** 0.5)
    bounds = functools.partial(_tile_bounds, block_q=block_q,
                               block_k=block_k, causal=causal, window=window)

    def kv_index(b, h, iq, ik, qoff, lens):
        # Tiles outside [first, last] revisit the last in-range tile: a
        # repeated block index means the pipeline skips the HBM->VMEM
        # copy (their compute is skipped by the kernel-body guard).
        first, last = bounds(qoff, lens, b, iq)
        return (b, h // group, jnp.minimum(first + ik, last), 0)

    def kv_index_paged(b, h, iq, ik, qoff, lens, bt):
        # Same logical pruning; the physical page comes from the block
        # table, so revisiting a logical tile revisits the same physical
        # page and the DMA-elision property is preserved.
        first, last = bounds(qoff, lens, b, iq)
        return (h // group, bt[b, jnp.minimum(first + ik, last)], 0, 0)

    if paged:
        q_idx = lambda b, h, iq, ik, qoff, lens, bt: (b, h, iq, 0)
        kv_idx = kv_index_paged
        n_prefetch, scalars = 3, (q_offset, lengths, block_tables)
    else:
        q_idx = lambda b, h, iq, ik, qoff, lens: (b, h, iq, 0)
        kv_idx = kv_index
        n_prefetch, scalars = 2, (q_offset, lengths)
    q_spec = pl.BlockSpec((1, 1, block_q, hd), q_idx)
    kv_specs = [pl.BlockSpec((1, 1, block_k, leaf.shape[3]), kv_idx)
                for leaf in kv_leaves]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_prefetch,
        grid=(B, H, nq, nk),
        in_specs=[q_spec] + kv_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, hd), q_idx),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
    )
    kern = functools.partial(kernel, causal=causal, window=window,
                             scale=scale, block_q=block_q, block_k=block_k,
                             num_kv_blocks=nk)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(*scalars, q, *kv_leaves)


def flash_prefill_bhsd(q, k, v, q_offset, lengths, *, causal: bool = True,
                       window: int = 0, block_q: int = 128,
                       block_k: int = 128, interpret: bool = False):
    """q: [B, H, Sq, hd]; k/v: [B, Hk, Sk, hd]; q_offset/lengths: [B]
    int32 -> [B, H, Sq, hd].  Sq/Sk are block multiples (caller pads)."""
    return _build(q, [k, v], q_offset, lengths, _prefill_kernel,
                  causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


def flash_prefill_quant_bhsd(q, k_q, k_s, v_q, v_s, q_offset, lengths, *,
                             causal: bool = True, window: int = 0,
                             block_q: int = 128, block_k: int = 128,
                             interpret: bool = False):
    """int8 KV variant: k_q/v_q: int8 [B, Hk, Sk, hd]; k_s/v_s:
    [B, Hk, Sk, 1] scales.  Dequantisation happens per tile in VMEM."""
    return _build(q, [k_q, k_s, v_q, v_s], q_offset, lengths,
                  _prefill_kernel_quant, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def flash_prefill_paged_bhsd(q, k_arena, v_arena, q_offset, lengths,
                             block_tables, *, causal: bool = True,
                             window: int = 0, block_q: int = 128,
                             interpret: bool = False):
    """Paged-layout chunk prefill: q [B, H, Sq, hd]; arenas
    [Hk, P_phys, page, hd]; block_tables [B, P_max] physical page ids
    (block_k = the page size).  Same pruning bounds as the slab kernel;
    the physical fetch goes through the table."""
    ps = k_arena.shape[2]
    return _build(q, [k_arena, v_arena], q_offset, lengths,
                  _prefill_kernel_paged, causal=causal, window=window,
                  block_q=block_q, block_k=ps, interpret=interpret,
                  block_tables=block_tables)


def flash_prefill_paged_quant_bhsd(q, k_q, k_s, v_q, v_s, q_offset, lengths,
                                   block_tables, *, causal: bool = True,
                                   window: int = 0, block_q: int = 128,
                                   interpret: bool = False):
    """int8 paged variant: value arenas [Hk, P_phys, page, hd] + scale
    arenas [Hk, P_phys, page, 1], all streamed through the same
    block-table index map."""
    ps = k_q.shape[2]
    return _build(q, [k_q, k_s, v_q, v_s], q_offset, lengths,
                  _prefill_kernel_paged_quant, causal=causal, window=window,
                  block_q=block_q, block_k=ps, interpret=interpret,
                  block_tables=block_tables)
