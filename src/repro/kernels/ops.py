"""Jitted public wrappers around the Pallas kernels.

These adapt the model-layer layout ([B, S, H, hd]) to the kernel layout,
pad sequences to tile multiples, and select interpret mode automatically
on non-TPU backends (the reproduction contract: TPU is the *target*,
interpret=True validates the kernel bodies on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.decode_attention import (flash_decode_bhgd,
                                            flash_decode_paged_bhgd)
from repro.kernels.moe_gmm import gmm_bcd
from repro.kernels.prefill_attention import (flash_prefill_bhsd,
                                             flash_prefill_paged_bhsd,
                                             flash_prefill_paged_quant_bhsd,
                                             flash_prefill_quant_bhsd)
from repro.kernels.ssd_scan import ssd_scan_bhsd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_seq(x, multiple: int, axis: int):
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, Hk, hd] -> [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    qt = _pad_seq(q.transpose(0, 2, 1, 3), block_q, 2)
    kt = _pad_seq(k.transpose(0, 2, 1, 3), block_k, 2)
    vt = _pad_seq(v.transpose(0, 2, 1, 3), block_k, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               sk_valid=k.shape[1],
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, block_k: int = 2048,
                 interpret: bool | None = None):
    """q: [B, 1, H, hd]; caches: [B, S, Hk, hd]; lengths: [B] (valid keys
    incl. current token) -> [B, 1, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, _, H, hd = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    qg = q[:, 0].reshape(B, Hk, G, hd)
    kt = _pad_seq(k_cache.transpose(0, 2, 1, 3), block_k, 2)
    vt = _pad_seq(v_cache.transpose(0, 2, 1, 3), block_k, 2)
    out = flash_decode_bhgd(qg, kt, vt, lengths.astype(jnp.int32),
                            block_k=min(block_k, kt.shape[2]),
                            interpret=interpret)
    return out.reshape(B, 1, H, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(q, k_arena, v_arena, lengths, block_tables, *,
                       interpret: bool | None = None):
    """Paged flash decode. q: [B, 1, H, hd]; arenas:
    [P_phys, page, Hk, hd]; lengths: [B]; block_tables: [B, P_max]
    physical page ids -> [B, 1, H, hd].  block_k = the page size."""
    if interpret is None:
        interpret = _interpret_default()
    B, _, H, hd = q.shape
    Hk = k_arena.shape[2]
    G = H // Hk
    qg = q[:, 0].reshape(B, Hk, G, hd)
    kt = k_arena.transpose(2, 0, 1, 3)          # [Hk, P_phys, page, hd]
    vt = v_arena.transpose(2, 0, 1, 3)
    out = flash_decode_paged_bhgd(qg, kt, vt, lengths.astype(jnp.int32),
                                  block_tables.astype(jnp.int32),
                                  interpret=interpret)
    return out.reshape(B, 1, H, hd)


def _prefill_blocks(Sq: int, block_q: int) -> int:
    """Query-tile size: capped at the (8-aligned) chunk length so short
    serving chunks are not padded up to a full 128-row tile."""
    return min(block_q, max(8, -(-Sq // 8) * 8))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_prefill(q, k_cache, v_cache, q_offset, lengths, *,
                  causal: bool = True, window: int = 0, block_q: int = 128,
                  block_k: int = 128, interpret: bool | None = None):
    """Cache-aware chunk prefill. q: [B, Sq, H, hd]; caches:
    [B, S, Hk, hd]; q_offset/lengths: [B] -> [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    block_q = _prefill_blocks(Sq, block_q)
    block_k = min(block_k, k_cache.shape[1])
    qt = _pad_seq(q.transpose(0, 2, 1, 3), block_q, 2)
    kt = _pad_seq(k_cache.transpose(0, 2, 1, 3), block_k, 2)
    vt = _pad_seq(v_cache.transpose(0, 2, 1, 3), block_k, 2)
    out = flash_prefill_bhsd(qt, kt, vt, q_offset.astype(jnp.int32),
                             lengths.astype(jnp.int32), causal=causal,
                             window=window, block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_prefill_quant(q, k_q, k_s, v_q, v_s, q_offset, lengths, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool | None = None):
    """int8-KV chunk prefill. k_q/v_q: int8 [B, S, Hk, hd]; k_s/v_s:
    [B, S, Hk, 1] scales -> [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    block_q = _prefill_blocks(Sq, block_q)
    block_k = min(block_k, k_q.shape[1])
    qt = _pad_seq(q.transpose(0, 2, 1, 3), block_q, 2)
    tr = lambda x: _pad_seq(x.transpose(0, 2, 1, 3), block_k, 2)
    out = flash_prefill_quant_bhsd(
        qt, tr(k_q), tr(k_s), tr(v_q), tr(v_s), q_offset.astype(jnp.int32),
        lengths.astype(jnp.int32), causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "interpret"))
def flash_prefill_paged(q, k_arena, v_arena, q_offset, lengths, block_tables,
                        *, causal: bool = True, window: int = 0,
                        block_q: int = 128, interpret: bool | None = None):
    """Paged chunk prefill. q: [B, Sq, H, hd]; arenas:
    [P_phys, page, Hk, hd]; block_tables: [B, P_max] -> [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    block_q = _prefill_blocks(Sq, block_q)
    qt = _pad_seq(q.transpose(0, 2, 1, 3), block_q, 2)
    kt = k_arena.transpose(2, 0, 1, 3)
    vt = v_arena.transpose(2, 0, 1, 3)
    out = flash_prefill_paged_bhsd(
        qt, kt, vt, q_offset.astype(jnp.int32), lengths.astype(jnp.int32),
        block_tables.astype(jnp.int32), causal=causal, window=window,
        block_q=block_q, interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "interpret"))
def flash_prefill_paged_quant(q, k_q, k_s, v_q, v_s, q_offset, lengths,
                              block_tables, *, causal: bool = True,
                              window: int = 0, block_q: int = 128,
                              interpret: bool | None = None):
    """int8-KV paged chunk prefill: value arenas [P_phys, page, Hk, hd]
    + scale arenas [P_phys, page, Hk, 1] -> [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    block_q = _prefill_blocks(Sq, block_q)
    qt = _pad_seq(q.transpose(0, 2, 1, 3), block_q, 2)
    tr = lambda x: x.transpose(2, 0, 1, 3)
    out = flash_prefill_paged_quant_bhsd(
        qt, tr(k_q), tr(k_s), tr(v_q), tr(v_s), q_offset.astype(jnp.int32),
        lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
        causal=causal, window=window, block_q=block_q, interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, h0, *, chunk: int = 128,
             interpret: bool | None = None):
    """SSD over a sequence, model layout.

    x: [B, S, nh, hd]; dt: [B, S, nh] (post-softplus); A: [nh] (negative);
    Bm/Cm: [B, S, N]; h0: [B, nh, hd, N].
    Returns (y [B, S, nh, hd], h_final)."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, nh, hd = x.shape
    xt = _pad_seq(x.transpose(0, 2, 1, 3), chunk, 2)
    dtt = _pad_seq(dt.transpose(0, 2, 1), chunk, 2)
    Bp = _pad_seq(Bm, chunk, 1)
    Cp = _pad_seq(Cm, chunk, 1)
    dtA = dtt * A[None, :, None]
    y, h = ssd_scan_bhsd(xt, dtt, dtA, Bp, Cp, h0,
                         chunk=min(chunk, xt.shape[2]), interpret=interpret)
    return y[:, :, :S].transpose(0, 2, 1, 3), h


def _pad_dims(x, multiples):
    pads = [(0, (-s) % m) for s, m in zip(x.shape, multiples)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=(
    "block_c", "block_f", "block_d", "interpret"))
def moe_gmm(x, w, *, block_c: int = 128, block_f: int = 512,
            block_d: int = 512, interpret: bool | None = None):
    """Grouped expert matmul. x: [E, C, d]; w: [E, d, f] -> [E, C, f]."""
    if interpret is None:
        interpret = _interpret_default()
    E, C, d = x.shape
    f = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, f), min(block_d, d)
    xp = _pad_dims(x, (1, bc, bd))
    wp = _pad_dims(w, (1, bd, bf))
    out = gmm_bcd(xp, wp, block_c=bc, block_f=bf, block_d=bd,
                  interpret=interpret)
    return out[:, :C, :f]
