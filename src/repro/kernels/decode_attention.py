"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

The decode hot loop is memory-bound (it must stream the KV cache from
HBM once); the kernel therefore tiles the cache sequence dimension into
``block_k`` VMEM tiles on the innermost sequential grid axis and keeps
the online-softmax state for all ``G = H / Hk`` query heads of one KV
head in VMEM scratch — the [G, hd] accumulator never round-trips to HBM.

Per-sequence valid lengths arrive via scalar prefetch
(``PrefetchScalarGridSpec``): they are needed *before* the tile loop to
mask cache padding, exactly the role scalar prefetch plays on TPU —
and, since they are available to the BlockSpec index maps, to *skip the
HBM traffic* of fully-out-of-range KV tiles, not just their compute:
tiles whose start lies beyond the sequence's valid length map back to
the last in-range tile index (the revisit-block trick), and the Pallas
pipeline elides the DMA when a block index repeats across consecutive
grid steps.  For a serving mix of short and long sequences this makes
per-sequence decode bytes O(length), not O(S_max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_k: int, num_kv_blocks: int, scale: float):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = ik * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # [G, bk]
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _decode_kernel_paged(len_ref, bt_ref, *args, **kw):
    """Paged variant: the block table is consumed by the BlockSpec index
    maps only — the kernel body is identical because tile positions are
    *logical* (``ik * block_k``) regardless of which physical page the
    pipeline fetched."""
    _decode_kernel(len_ref, *args, **kw)


def flash_decode_paged_bhgd(
    q, k_arena, v_arena, lengths, block_tables, *, interpret: bool = False,
):
    """Block-table flash decode over a paged KV arena (DESIGN.md §8).

    q: [B, Hk, G, hd]; arenas: [Hk, P_phys, page, hd]; lengths: [B];
    block_tables: [B, P_max] int32 physical page ids (entries beyond a
    session's valid length may point anywhere mapped — they are never
    fetched).  ``block_k`` is the page size.  The k-tile grid index maps
    through the scalar-prefetched table: logical tile ``ik`` fetches
    physical page ``bt[b, min(ik, nvalid-1)]``, and fully-out-of-range
    tiles revisit the last in-range page so the pipeline elides their
    DMA — the same O(length) bytes bound as the slab kernel, now with
    zero-copy page sharing between sessions."""
    B, Hk, G, hd = q.shape
    ps = k_arena.shape[2]
    nk = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _decode_kernel_paged, block_k=ps, num_kv_blocks=nk, scale=scale)

    def kv_index(b, h, ik, lens, bt):
        nvalid = jnp.maximum((lens[b] + ps - 1) // ps, 1)
        return (h, bt[b, jnp.minimum(ik, nvalid - 1)], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, ik, lens, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), kv_index),
            pl.BlockSpec((1, 1, ps, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ik, lens, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, block_tables, q, k_arena, v_arena)


def flash_decode_bhgd(
    q, k_cache, v_cache, lengths, *, block_k: int = 2048,
    interpret: bool = False,
):
    """q: [B, Hk, G, hd]; caches: [B, Hk, S, hd]; lengths: [B] (tokens
    valid in the cache, including the current one) -> [B, Hk, G, hd]."""
    B, Hk, G, hd = q.shape
    S = k_cache.shape[2]
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _decode_kernel, block_k=block_k, num_kv_blocks=nk, scale=scale)

    def kv_index(b, h, ik, lens):
        # Tiles fully beyond the valid length revisit the last in-range
        # tile: a repeated block index means the pipeline skips the
        # HBM->VMEM copy (their compute is already skipped by the
        # ``pl.when`` guard in the kernel body).
        nvalid = jnp.maximum((lens[b] + block_k - 1) // block_k, 1)
        return (b, h, jnp.minimum(ik, nvalid - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hk, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), kv_index),
            pl.BlockSpec((1, 1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
