"""Pallas TPU flash-attention (prefill/train) kernel.

Design for the TPU memory hierarchy (DESIGN.md §2): queries are tiled
into ``block_q``-row VMEM tiles, the KV sequence is streamed through
VMEM in ``block_k`` tiles along the innermost (sequential) grid
dimension, and the online-softmax accumulators (m, l, acc) live in VMEM
scratch so nothing spills to HBM between KV tiles.  Block sizes default
to 128 — MXU-aligned (128x128 systolic array) and a multiple of the
(8, 128) float32 / (16, 128) bf16 min tile.

GQA is expressed in the index maps: the K/V BlockSpecs map query-head
``h`` to kv-head ``h // group`` so KV tiles are fetched once per kv head
group, never repeated in HBM.

Causal + sliding-window masking is computed from block-local iotas;
fully-masked KV tiles are skipped with ``pl.when`` (the TPU grid is
sequential, so a skipped tile costs only the (cheap) guard evaluation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, sk_valid: int, scale: float,
                  block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # skip tiles that are entirely masked out
    live = k_start < sk_valid
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
        if window > 0:
            live = jnp.logical_and(
                live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < sk_valid
        if causal:
            mask = mask & (k_pos <= q_pos)
            if window > 0:
                mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(
    q, k, v, *, causal: bool = True, window: int = 0, sk_valid: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q: [B, H, Sq, hd]; k/v: [B, Hk, Sk, hd] -> [B, H, Sq, hd].

    Sq/Sk are padded to block multiples by the caller (``ops.py``);
    ``sk_valid`` (the unpadded K length) masks the K padding, and the
    caller slices away Q padding."""
    B, H, Sq, hd = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    group = H // Hk
    sk_valid = sk_valid or Sk
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, sk_valid=sk_valid,
        scale=scale, block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
