"""Pallas TPU grouped matmul for the MoE expert FFN.

The MoE hot spot after dispatch is ``[E, C, d] x [E, d, f] -> [E, C, f]``
— E independent matmuls over capacity-bounded token rows.  Tiling for
the MXU: per grid step one (expert, C-tile, f-tile) block with the
contraction dimension d streamed through VMEM in ``block_d`` tiles on
the innermost sequential axis; a float32 VMEM scratch accumulates
partial products so nothing round-trips HBM between d-tiles.

Grid: (E, C/bc, f/bf, d/bd) — d innermost (sequential on TPU), so the
[bc, bf] accumulator lives across the d loop.  Block sizes default to
MXU-aligned 128/512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, num_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)           # [bc, bd]
    w = w_ref[0].astype(jnp.float32)           # [bd, bf]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(di == num_d_blocks - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm_bcd(x, w, *, block_c: int = 128, block_f: int = 512,
            block_d: int = 512, interpret: bool = False):
    """x: [E, C, d]; w: [E, d, f] -> [E, C, f]."""
    E, C, d = x.shape
    f = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, f), min(block_d, d)
    assert C % bc == 0 and f % bf == 0 and d % bd == 0, (C, f, d)
    grid = (E, C // bc, f // bf, d // bd)

    kernel = functools.partial(_gmm_kernel, num_d_blocks=d // bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
