"""Path-based PartitionSpec rules for every architecture and input shape.

Sharding scheme:

* tensor parallelism over ``model`` (16-wide): attention/SSM head
  projections, MLP + expert d_ff, vocab for embed/lm_head.
* batch parallelism over ``data`` (+ ``pod``): training batch, decode
  batch, prefill batch.
* ``fsdp`` mode additionally shards the d_model dimension of every
  matmul weight (and optimizer state) over the data(+pod) axes —
  required for the >100B configs (Mixtral-8x22B, Jamba-1.5-Large) whose
  replicated-over-data parameters would not fit HBM.
* decode caches: KV batch over data(+pod); head_dim over ``model``
  (kv-head counts of the assigned archs — 2..16 — do not divide the
  16-wide model axis, head_dim always does); for long_500k (batch=1) the
  cache *sequence* is sharded over data instead (flash-decode-style
  sequence parallelism).

All specs are returned as pytrees of ``PartitionSpec`` matching the
params/cache trees, suitable for ``NamedSharding(mesh, spec)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = False          # shard d_model dims over data(+pod)
    seq_shard_long: bool = True  # long_500k: shard cache seq over data


def auto_policy(cfg: ModelConfig) -> ShardingPolicy:
    """fsdp once replicated-over-data optimizer state would dominate HBM
    (~>4B params: f32 m+v replicated over 16-wide data would be >2 GB)."""
    return ShardingPolicy(fsdp=cfg.param_count() > 4e9)


MODEL = "model"


def _axes(mesh: Mesh) -> Tuple:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def param_specs(cfg: ModelConfig, mesh: Mesh,
                policy: Optional[ShardingPolicy] = None):
    """PartitionSpec pytree matching ``init_params(cfg, ...)``."""
    policy = policy or auto_policy(cfg)
    F = _axes(mesh) if policy.fsdp else None
    from repro.models import params_shape  # late import (no jax state)
    shapes = params_shape(cfg)

    def spec_for(path, leaf) -> P:
        names = [getattr(k, "key", str(k)) for k in path]
        name = names[-1]
        in_group = names[0] == "groups"
        nd = len(leaf.shape)

        if name == "embed":
            return P(MODEL, F)
        if name == "lm_head":
            return P(F, MODEL)
        if name in ("final_norm",):
            return P(None)
        # ---- grouped (stacked) leaves: axis 0 is the group axis -------
        if name in ("norm1", "norm2"):
            return P(None, None)
        if names[-2] == "attn":
            if name in ("wq", "wk", "wv"):
                return P(None, F, MODEL)
            if name == "wo":
                return P(None, MODEL, F)
        if names[-2] == "ssm":
            if name in ("w_z", "w_x", "w_dt"):
                return P(None, F, MODEL)
            if name in ("w_B", "w_C"):
                return P(None, F, None)
            if name == "w_out":
                return P(None, MODEL, F)
            if name in ("conv_x_w",):
                return P(None, None, MODEL)
            if name in ("conv_x_b", "dt_bias", "A_log", "D", "norm"):
                return P(None, MODEL)
            if name in ("conv_B_w", "conv_C_w"):
                return P(None, None, None)
            if name in ("conv_B_b", "conv_C_b"):
                return P(None, None)
        if names[-2] == "ffn":
            if name == "router":
                return P(None, F, None)
            if nd == 4:  # MoE experts [G, E, d, f]
                if name == "w_down":
                    return P(None, None, MODEL, F)
                return P(None, None, F, MODEL)
            if name == "w_down":
                return P(None, MODEL, F)
            return P(None, F, MODEL)
        raise ValueError(f"no sharding rule for {'/'.join(names)}")

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def cache_specs(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                kv_quant: bool = False, seqpar: bool = False):
    """PartitionSpec pytree matching ``init_cache`` for a decode shape.

    ``seqpar``: the shard_map sequence-parallel flash-decode owns the dp
    axes for the cache sequence dim and replicates head_dim (its LSE
    merge needs full-hd partial accumulators)."""
    dp = _axes(mesh)
    long_ctx = shape.global_batch < 8      # long_500k: batch unshardable
    from repro.models import cache_shape
    shapes = cache_shape(cfg, shape.global_batch, shape.seq_len,
                         kv_quant=kv_quant)

    all_axes = tuple(mesh.axis_names)

    def spec_for(path, leaf) -> P:
        name = getattr(path[-1], "key", str(path[-1]))
        if name in ("k", "v", "ks", "vs"):  # [G, B, S, Hk, hd|1]
            if seqpar:
                if not long_ctx:
                    # decode_32k: batch over data(+pod), seq over model
                    return P(None, dp, MODEL, None, None)
                # long_500k: sequence sharded over the WHOLE mesh — the
                # model axis carries no decode-layer role at batch 1, so
                # it joins the flash-decode seq-parallel axis (§Perf 2c)
                return P(None, None, all_axes, None, None)
            hd_ax = None if name in ("ks", "vs") else MODEL
            if long_ctx:
                return P(None, None, dp, None, hd_ax)
            return P(None, dp, None, None, hd_ax)
        if name in ("conv_x",):            # [G, B, w, d_in]
            return P(None, None if long_ctx else dp, None, MODEL)
        if name in ("conv_B", "conv_C"):
            return P(None, None if long_ctx else dp, None, None)
        if name == "ssd":                  # [G, B, nh, hd, N]
            return P(None, None if long_ctx else dp, MODEL, None, None)
        raise ValueError(f"no cache rule for {name}")

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def batch_specs(cfg: ModelConfig, mesh: Mesh, shape: InputShape):
    """Specs for the step-function data inputs."""
    dp = _axes(mesh)
    if shape.kind == "train" or shape.kind == "prefill":
        tok = P(dp, None)
        emb = P(dp, None, None)
        return {"tokens": tok, "embeds": emb, "labels": tok}
    # decode: tokens [B], lengths [B]
    if shape.global_batch < 8:
        return {"tokens": P(None), "lengths": P(None)}
    return {"tokens": P(dp), "lengths": P(dp)}


def opt_state_specs(pspecs):
    """Optimizer state mirrors parameter sharding; step is replicated."""
    from repro.training.optimizer import OptState
    return OptState(step=P(), m=pspecs, v=pspecs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
