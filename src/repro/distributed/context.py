"""Ambient SPMD context for model-internal distribution decisions.

Model code is mesh-agnostic; launchers activate an ``SPMDContext`` while
tracing so specific layers can opt into mesh-aware execution:

* ``apply_moe`` switches its gmm dispatch to a ``shard_map`` (per-device
  sort/scatter + tensor-parallel psum) — XLA SPMD cannot partition a
  global sort/scatter and otherwise replicates the full token stream
  (measured: 172 GB/device for one OLMoE layer at train_4k).
* ``_scan_groups`` stores its inter-group carries sequence-sharded over
  the tensor axis (Megatron-style sequence parallelism) so deep models'
  scan carries stay within HBM.

The CPU serving engine and the smoke tests never activate a context and
use the plain local paths.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map``: top-level ``jax.shard_map`` on
    newer jax, ``jax.experimental.shard_map`` (same contract) on the
    pinned 0.4.x toolchain."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


@dataclasses.dataclass(frozen=True)
class SPMDContext:
    mesh: Mesh
    dp_axes: Tuple[str, ...]      # batch/token-parallel axes ("pod","data")
    tp_axis: str = "model"
    shard_activations: bool = True   # sequence-shard scan carries
    fsdp: bool = False               # weights d-dim sharded over dp_axes
    batch_axes: Tuple[str, ...] = ()  # decode-batch axes (seqpar kernels)

    @property
    def dp_size(self) -> int:
        return int(
            __import__("numpy").prod([self.mesh.shape[a]
                                      for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])


_CTX: contextvars.ContextVar[Optional[SPMDContext]] = \
    contextvars.ContextVar("repro_spmd", default=None)


@contextlib.contextmanager
def spmd_context(ctx: SPMDContext):
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def current_spmd() -> Optional[SPMDContext]:
    return _CTX.get()


def spmd_for_mesh(mesh: Mesh, **kw) -> SPMDContext:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return SPMDContext(mesh=mesh, dp_axes=dp, **kw)
