"""Shared model primitives: init helpers, norms, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    if name == "swiglu":  # handled at mlp level; gate act is silu
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
