from repro.models.model import (  # noqa: F401
    cache_shape, forward_cold, forward_decode, forward_prefill,
    forward_train, group_layout, init_cache, init_params, params_shape)
