from repro.models.model import (  # noqa: F401
    POSITIONAL_CACHE_KEYS, cache_shape, forward_cold, forward_decode,
    forward_decode_fused, forward_decode_megastep, forward_prefill,
    forward_resume_batch, forward_train, group_layout, init_cache,
    init_params, merge_decode_cache, num_kv_pages, params_shape)
