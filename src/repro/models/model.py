"""Model assembly: stacked repeating groups + ``lax.scan`` over them.

All architectures are expressed as ``num_groups`` repetitions of a
statically-described *group* of layers (``group_size`` =
lcm(hybrid_period, moe.every), e.g. Jamba: 9 groups x 8 layers).  Per
layer-slot parameters are stacked along a leading ``num_groups`` axis so
the whole depth compiles as a single scanned HLO body — this keeps the
80 dry-run compiles tractable and is also how remat is applied.

Public entry points:
  init_params / params_shape      — weights (or their ShapeDtypeStructs)
  init_cache  / cache_shape       — decode caches (KV + SSM state)
  forward_train                   — full causal (or encoder) forward
  forward_prefill                 — chunk prefill writing into a cache
  forward_decode                  — one token per active sequence
  forward_decode_fused            — decode + greedy sample + cache merge,
                                    fully device-resident (DESIGN.md §3)
  forward_decode_megastep         — K fused decode steps in one lax.scan
  forward_resume_batch            — M resume prefills packed in one call
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2
from repro.models.blocks import (LayerSpec, apply_layer, init_layer,
                                 layer_specs_for_group)
from repro.models.common import embed_init, rms_norm, split_keys


def group_layout(cfg: ModelConfig) -> Tuple[int, int, Tuple[LayerSpec, ...]]:
    period = cfg.hybrid_period or 1
    every = cfg.moe.every if cfg.moe else 1
    group_size = math.lcm(period, every)
    assert cfg.num_layers % group_size == 0, (cfg.name, group_size)
    return cfg.num_layers // group_size, group_size, layer_specs_for_group(cfg, group_size)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    G, gs, specs = group_layout(cfg)
    k_embed, k_groups, k_head = split_keys(key, 3)

    def one_group(k):
        ks = split_keys(k, gs)
        return {f"l{j}": init_layer(ks[j], cfg, specs[j], dtype)
                for j in range(gs)}

    gkeys = split_keys(k_groups, G)
    groups = jax.tree.map(lambda *xs: jnp.stack(xs), *[one_group(k) for k in gkeys])
    params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "groups": groups,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model,
                                       dtype).T
    return params


def params_shape(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


def num_kv_pages(cfg: ModelConfig, batch: int, max_seq: int) -> int:
    """Default usable page count for the paged layout: capacity parity
    with the slab layout (``batch`` full-length stripes)."""
    return batch * (-(-max_seq // cfg.kv_page_size))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.float32, kv_quant: bool = False,
               num_pages: int = 0) -> Dict[str, Any]:
    """Decode/serving cache for one model: stacked over groups.
    ``kv_quant``: int8 values + per-(position, head) scales (§Perf).

    With ``cfg.kv_layout == "paged"`` the *positional* leaves (attention
    K/V and quant scales) become a flat page arena
    ``[G, num_pages + 1, page_size, Hk, hd]`` addressed through
    per-session block tables (DESIGN.md §8); the extra last page is the
    write scratch page (never read).  SSM/stateful leaves stay per-slot
    point summaries — a recurrent state is a length-point snapshot, not
    a positional row (the Marconi argument), so paging it would buy
    nothing and break the COW sharing invariants."""
    G, gs, specs = group_layout(cfg)
    paged = cfg.kv_layout == "paged"
    if paged:
        assert max_seq % cfg.kv_page_size == 0, (max_seq, cfg.kv_page_size)
        if num_pages <= 0:
            num_pages = num_kv_pages(cfg, batch, max_seq)
    cache: Dict[str, Any] = {}
    for j, spec in enumerate(specs):
        if spec.kind == "attn":
            if paged:
                shape = (G, num_pages + 1, cfg.kv_page_size,
                         cfg.num_kv_heads, cfg.head_dim)
            else:
                shape = (G, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
            # k/v (and scale) leaves must be *distinct* buffers: donating
            # executables (fused decode, batched resume, fused prefix
            # restore) reject a pytree that donates one buffer twice
            if kv_quant:
                cache[f"l{j}"] = {"k": jnp.zeros(shape, jnp.int8),
                                  "v": jnp.zeros(shape, jnp.int8),
                                  "ks": jnp.zeros(shape[:-1] + (1,), dtype),
                                  "vs": jnp.zeros(shape[:-1] + (1,), dtype)}
                continue
            cache[f"l{j}"] = {"k": jnp.zeros(shape, dtype),
                              "v": jnp.zeros(shape, dtype)}
        else:
            st = mamba2.init_ssm_state(batch, cfg.d_model, cfg.ssm, dtype)
            cache[f"l{j}"] = {
                k: jnp.zeros((G,) + v.shape, v.dtype)
                for k, v in st._asdict().items()
            }
    return cache


def cache_shape(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.float32, kv_quant: bool = False,
                num_pages: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_seq, dtype, kv_quant, num_pages))


# ---------------------------------------------------------------------------
# forward core
# ---------------------------------------------------------------------------


def _sqrt_divisor(n: int) -> int:
    best = 1
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            best = d
    return best


def _scan_groups(params, x, cfg: ModelConfig, *, mode: str, positions,
                 lengths, cache, window: int, moe_mode: str,
                 remat: bool = False, block_size: int = 512,
                 moe_capacity: float = 1.25, moe_shards: int = 1,
                 seq_parallel=None, block_tables=None,
                 write_positions=None, ssm_valid=None):
    G, gs, specs = group_layout(cfg)
    from repro.distributed.context import current_spmd
    spmd = current_spmd()
    S = x.shape[1]
    constrain = (spmd is not None and spmd.shard_activations
                 and mode in ("train", "encode")
                 and S % spmd.tp_size == 0 and S > 1)

    def body(carry, xs):
        h, aux = carry
        gparams, gcache = xs
        new_gcache = {} if gcache is not None else None
        for j, spec in enumerate(specs):
            lc = gcache.get(f"l{j}") if gcache is not None else None
            if spec.kind == "ssm" and lc is None:
                # train/cold path still needs a zero state to scan from
                st = mamba2.init_ssm_state(h.shape[0], cfg.d_model, cfg.ssm,
                                           h.dtype)
                lc = st._asdict()

            def layer_fn(lp, h_in, lc_in, _spec=spec):
                return apply_layer(
                    lp, h_in, cfg, _spec, mode=mode,
                    positions=positions, lengths=lengths, layer_cache=lc_in,
                    window=window, moe_mode=moe_mode, block_size=block_size,
                    moe_capacity=moe_capacity, moe_shards=moe_shards,
                    seq_parallel=seq_parallel, block_tables=block_tables,
                    write_positions=write_positions, ssm_valid=ssm_valid)

            if remat and gs > 1:
                # per-layer remat within the group body: without this, a
                # multi-layer group (Jamba: 8) keeps every layer's
                # residuals live at once during the body's backward
                layer_fn = jax.checkpoint(layer_fn)
            h, lc, a = layer_fn(gparams[f"l{j}"], h, lc)
            aux = aux + a
            if new_gcache is not None:
                new_gcache[f"l{j}"] = lc
        if constrain:
            # Megatron-style sequence parallelism for the stored carry:
            # scan carries persist per iteration; sharding them over the
            # tensor axis divides that storage by tp_size (the re-gather
            # happens at the next group's attention anyway).
            from jax.sharding import NamedSharding, PartitionSpec as P
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(spmd.mesh,
                                 P(spmd.dp_axes, spmd.tp_axis, None)))
        return (h, aux), new_gcache

    if remat:
        body = jax.checkpoint(body)

    aux0 = jnp.zeros((), jnp.float32)
    G1 = _sqrt_divisor(G) if (remat and cache is None) else 1
    if G1 > 1:
        # 2-level (sqrt-depth) remat scan: peak carry storage drops from
        # G * |h| to (G1 + G/G1) * |h| at one extra forward recompute.
        G2 = G // G1
        xs2 = jax.tree.map(
            lambda a: a.reshape((G1, G2) + a.shape[1:]), params["groups"])

        def outer(carry, xs):
            return jax.lax.scan(body, carry, xs)

        (x, aux), _ = jax.lax.scan(jax.checkpoint(outer), (x, aux0),
                                   (xs2, None))
        return x, aux, None

    (x, aux), new_cache = jax.lax.scan(body, (x, aux0),
                                       (params["groups"], cache))
    return x, aux, new_cache


def _logits(params, cfg: ModelConfig, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["embed"].T if cfg.tie_embeddings
              else h @ params["lm_head"])
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    return logits


def _embed(params, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is not None:
        return embeds
    return params["embed"][tokens]


# ---------------------------------------------------------------------------
# public forwards
# ---------------------------------------------------------------------------


def forward_train(params, cfg: ModelConfig, tokens=None, *, embeds=None,
                  positions=None, moe_mode: str = "gmm", remat: bool = False,
                  window_override: Optional[int] = None,
                  block_size: int = 512, moe_capacity: float = 1.25,
                  moe_shards: int = 1, return_hidden: bool = False):
    """Full forward producing logits for every position.

    ``embeds`` (instead of ``tokens``) is the sanctioned modality-stub
    entry point for audio/VLM frontends."""
    x = _embed(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mode = "encode" if cfg.encoder_only else "train"
    window = cfg.sliding_window if window_override is None else window_override
    lengths = jnp.zeros((B,), jnp.int32)
    h, aux, _ = _scan_groups(params, x, cfg, mode=mode, positions=positions,
                             lengths=lengths, cache=None, window=window,
                             moe_mode=moe_mode, remat=remat,
                             block_size=block_size, moe_capacity=moe_capacity,
                             moe_shards=moe_shards)
    if return_hidden:
        return h, aux
    return _logits(params, cfg, h), aux


def forward_cold(params, cfg: ModelConfig, tokens=None, *, embeds=None,
                 moe_mode: str = "gmm", remat: bool = False,
                 window_override: Optional[int] = None,
                 block_size: int = 512, moe_shards: int = 1):
    """Cold prefill without a persistent cache: full causal (or encoder)
    forward returning ONLY the last-position logits [B, vocab] — the
    serving TTFT path, and the prefill_32k dry-run step (materialising
    [B, S, vocab] logits at 32k would not fit HBM)."""
    x = _embed(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mode = "encode" if cfg.encoder_only else "train"
    window = cfg.sliding_window if window_override is None else window_override
    lengths = jnp.zeros((B,), jnp.int32)
    h, aux, _ = _scan_groups(params, x, cfg, mode=mode, positions=positions,
                             lengths=lengths, cache=None, window=window,
                             moe_mode=moe_mode, remat=remat,
                             block_size=block_size, moe_shards=moe_shards)
    return _logits(params, cfg, h[:, -1:, :])[:, 0]


def forward_prefill(params, cfg: ModelConfig, tokens, cache, lengths, *,
                    embeds=None, moe_mode: str = "gmm",
                    window_override: Optional[int] = None,
                    block_size: int = 512, moe_capacity: float = 1.25,
                    moe_shards: int = 1, logit_idx=None, block_tables=None):
    """Process a chunk (cold or resume prefill), writing into ``cache``.

    tokens: [B, S] appended at per-batch offsets ``lengths`` [B].
    ``logit_idx`` [B]: position within the chunk whose logits to return
    (defaults to the last — engines pass the last *unpadded* position).
    ``block_tables`` [B, P_max] selects the paged cache layout: chunk
    rows scatter into the page arena through the table instead of into
    per-slot stripes (DESIGN.md §8).
    Returns (logits [B, vocab], new_cache, new_lengths)."""
    x = _embed(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    positions = lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    window = cfg.sliding_window if window_override is None else window_override
    # logit_idx marks the last real token per row, so it also fences the
    # SSM state update against executable-shape padding (mamba2.py)
    ssm_valid = None if logit_idx is None else logit_idx + 1
    h, aux, new_cache = _scan_groups(
        params, x, cfg, mode="prefill", positions=positions, lengths=lengths,
        cache=cache, window=window, moe_mode=moe_mode,
        block_size=block_size, moe_capacity=moe_capacity,
        moe_shards=moe_shards, block_tables=block_tables,
        ssm_valid=ssm_valid)
    if logit_idx is None:
        h_last = h[:, -1:, :]
    else:
        h_last = jnp.take_along_axis(h, logit_idx[:, None, None], axis=1)
    logits = _logits(params, cfg, h_last)[:, 0]
    return logits, new_cache, lengths + S


def forward_decode(params, cfg: ModelConfig, tokens, cache, lengths, *,
                   moe_mode: str = "gmm",
                   window_override: Optional[int] = None,
                   moe_capacity: float = 1.25, moe_shards: int = 1,
                   seq_parallel=None, block_tables=None,
                   write_positions=None):
    """One decode step. tokens: [B] (last sampled token per sequence).

    ``write_positions`` [B] decouples where the new K/V row lands from
    the attention valid-length: the fused hot path redirects *inactive*
    lanes' writes to scratch while their attention extent stays
    O(real length) — without it ``lengths`` would have to be pinned to
    the scratch position for idle lanes (the DESIGN.md §3 follow-up).
    Defaults to ``lengths`` (the seed behaviour).
    Returns (logits [B, vocab], new_cache, new_lengths)."""
    x = _embed(params, cfg, tokens[:, None])
    B = x.shape[0]
    positions = lengths[:, None]
    window = cfg.sliding_window if window_override is None else window_override
    h, aux, new_cache = _scan_groups(
        params, x, cfg, mode="decode", positions=positions, lengths=lengths,
        cache=cache, window=window, moe_mode=moe_mode,
        moe_capacity=moe_capacity, moe_shards=moe_shards,
        seq_parallel=seq_parallel, block_tables=block_tables,
        write_positions=write_positions)
    logits = _logits(params, cfg, h[:, 0, :])
    return logits, new_cache, lengths + 1


# ---------------------------------------------------------------------------
# device-resident serving hot path (DESIGN.md §3)
# ---------------------------------------------------------------------------

# Cache leaves whose writes are *positional* (landing at sequence offsets
# derived from ``lengths``) as opposed to *stateful* (a full overwrite of
# a recurrent state every step).  Positional leaves never need a masked
# merge: a lane's write lands at its first invalid position, which is
# only ever read after a later prefill has overwritten it.
POSITIONAL_CACHE_KEYS = frozenset({"k", "v", "ks", "vs"})


def merge_decode_cache(new_cache, old_cache, active):
    """Merge a decode step's cache updates under an active-lane mask.

    Stateful (SSM) leaves are where-selected per batch lane so inactive
    sessions' recurrent states are not advanced by masked lanes; purely
    positional (attention KV) leaves pass through untouched — combined
    with the scratch-row write redirection in ``forward_decode_fused``
    this removes the O(full-cache) where-select the host-side
    ``KVCachePool.commit`` pays per token."""
    def merge_layer(new_l, old_l):
        if set(new_l) <= POSITIONAL_CACHE_KEYS:
            return new_l
        out = {}
        for k, n in new_l.items():
            shape = (1, n.shape[1]) + (1,) * (n.ndim - 2)
            out[k] = jnp.where(active.reshape(shape), n, old_l[k])
        return out
    return {name: merge_layer(layer, old_cache[name])
            for name, layer in new_cache.items()}


def _scratch_write_lengths(cache, lengths, active):
    """Redirect inactive lanes' positional writes to the cache's last
    sequence row (the scratch row — engines must keep real content out
    of it; see DESIGN.md §3).  Attention-free caches need no redirect."""
    for layer in cache.values():
        if "k" in layer:
            return jnp.where(active, lengths,
                             jnp.int32(layer["k"].shape[2] - 1))
    return lengths


def forward_decode_fused(params, cfg: ModelConfig, tokens, cache, lengths,
                         active, *, moe_mode: str = "gmm",
                         window_override: Optional[int] = None,
                         moe_capacity: float = 1.25, moe_shards: int = 1,
                         block_tables=None):
    """One decode step with greedy sampling, length increment and the
    active-lane cache merge folded in, so a serving engine can keep
    ``tokens``/``lengths``/``active`` as device arrays and never sync
    per token (DESIGN.md §3).

    tokens: [B] int32 (last token per lane; don't-care where inactive);
    active: [B] bool.  Returns (next_tokens [B], new_cache, new_lengths);
    inactive lanes keep their token and length unchanged, and their only
    cache writes land in the scratch row (slab) / scratch page (paged).
    Attention valid-length stays the *real* ``lengths`` for every lane —
    only the write position is redirected — so idle lanes cost O(real
    length), not O(max_seq), under a tile-skipping kernel."""
    if block_tables is not None:
        # paged: a negative write position redirects to the scratch page
        write_positions = jnp.where(active, lengths, -1)
    else:
        write_positions = _scratch_write_lengths(cache, lengths, active)
    logits, new_cache, _ = forward_decode(
        params, cfg, tokens, cache, lengths, moe_mode=moe_mode,
        window_override=window_override, moe_capacity=moe_capacity,
        moe_shards=moe_shards, block_tables=block_tables,
        write_positions=write_positions)
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    next_tokens = jnp.where(active, next_tokens, tokens)
    merged = merge_decode_cache(new_cache, cache, active)
    return next_tokens, merged, lengths + active.astype(jnp.int32)


def forward_decode_megastep(params, cfg: ModelConfig, tokens, cache,
                            lengths, active, *, num_steps: int,
                            moe_mode: str = "gmm",
                            window_override: Optional[int] = None,
                            moe_capacity: float = 1.25, moe_shards: int = 1,
                            block_tables=None):
    """``num_steps`` fused decode iterations as one ``lax.scan``
    executable, amortising dispatch over K emitted tokens per lane.

    Paged callers must have grown each active lane's block table to
    cover ``lengths + num_steps`` before dispatch — the table is fixed
    for the whole scan (``KVCachePool`` does this in
    ``prepare_append``).

    Returns (tokens_seq [K, B], next_tokens [B], new_cache, new_lengths);
    ``tokens_seq[i]`` is the token emitted by step i (inactive lanes
    repeat their input token)."""
    def body(carry, _):
        t, l, c = carry
        nt, nc, nl = forward_decode_fused(
            params, cfg, t, c, l, active, moe_mode=moe_mode,
            window_override=window_override, moe_capacity=moe_capacity,
            moe_shards=moe_shards, block_tables=block_tables)
        return (nt, nl, nc), nt

    (t, l, c), toks = jax.lax.scan(body, (tokens, lengths, cache), None,
                                   length=num_steps)
    return toks, t, c, l


def forward_resume_batch(params, cfg: ModelConfig, tokens, cache, slot_idx,
                         lengths, logit_idx, *, moe_mode: str = "gmm",
                         window_override: Optional[int] = None,
                         block_size: int = 512, moe_capacity: float = 1.25,
                         moe_shards: int = 1, block_tables=None):
    """Batched resume prefill: M jobs packed as one [M, bucket] chunk.

    tokens: [M, S]; slot_idx: [M] int32 (distinct cache slots);
    lengths: [M] (cached tokens per slot); logit_idx: [M] (last unpadded
    position per row).  Gathers the M slot rows out of the stacked
    cache, runs one batch-M prefill, and scatters the rows back.

    Under the paged layout (``block_tables`` [B, P_max]) only the
    *stateful* (SSM) leaves are gathered/scattered by slot — positional
    leaves are the shared page arena, which the prefill addresses
    directly through the M gathered block-table rows.
    Returns (logits [M, vocab], new_cache)."""
    if block_tables is not None:
        sub = {name: (layer if set(layer) <= POSITIONAL_CACHE_KEYS else
                      {k: jnp.take(v, slot_idx, axis=1)
                       for k, v in layer.items()})
               for name, layer in cache.items()}
        logits, sub2, _ = forward_prefill(
            params, cfg, tokens, sub, lengths, moe_mode=moe_mode,
            window_override=window_override, block_size=block_size,
            moe_capacity=moe_capacity, moe_shards=moe_shards,
            logit_idx=logit_idx,
            block_tables=jnp.take(block_tables, slot_idx, axis=0))
        new_cache = {
            name: (sub2[name] if set(layer) <= POSITIONAL_CACHE_KEYS else
                   {k: v.at[:, slot_idx].set(sub2[name][k])
                    for k, v in layer.items()})
            for name, layer in cache.items()}
        return logits, new_cache
    sub = jax.tree.map(lambda leaf: jnp.take(leaf, slot_idx, axis=1), cache)
    logits, sub2, _ = forward_prefill(
        params, cfg, tokens, sub, lengths, moe_mode=moe_mode,
        window_override=window_override, block_size=block_size,
        moe_capacity=moe_capacity, moe_shards=moe_shards,
        logit_idx=logit_idx)
    new_cache = jax.tree.map(
        lambda full, rows: full.at[:, slot_idx].set(rows), cache, sub2)
    return logits, new_cache
