"""Feed-forward layers: SwiGLU (llama-family) and GeLU (StarCoder2/HuBERT)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    if act == "swiglu":
        kg, ku, kd = split_keys(key, 3)
        return {
            "w_gate": dense_init(kg, d_model, d_ff, dtype),
            "w_up": dense_init(ku, d_model, d_ff, dtype),
            "w_down": dense_init(kd, d_ff, d_model, dtype),
        }
    ku, kd = split_keys(key, 2)
    return {
        "w_up": dense_init(ku, d_model, d_ff, dtype),
        "w_down": dense_init(kd, d_ff, d_model, dtype),
    }


def apply_mlp(params, x, act: str):
    if act == "swiglu":
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]
