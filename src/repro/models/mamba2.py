"""Mamba-2 (SSD — state-space duality) layer [arXiv:2405.21060].

Recurrence (per head h, scalar decay):
    a_t = exp(dt_t * A_h)                       (A_h < 0)
    H_t = a_t * H_{t-1} + dt_t * x_t (x) B_t    (H: [hd, N])
    y_t = H_t . C_t + D_h * x_t

Training/prefill uses the chunked SSD algorithm: a ``lax.scan`` over
chunks carries the inter-chunk state; inside a chunk the quadratic
"attention-like" form computes the diagonal block.  Peak memory is
O(B * Q^2 * nh) per chunk, not O(S^2).

Decode is the O(1)-per-token recurrence against the carried (conv,
ssd-state) cache — this is why SSM/hybrid archs run long_500k natively.

B and C are shared across heads (ngroups=1), matching mamba2-780m.
Weights are kept as separate projections (w_z/w_x/w_B/w_C/w_dt) instead
of one fused in_proj so each piece can carry its own PartitionSpec
(heads sharded over 'model', B/C replicated) — functionally identical.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm, split_keys
from repro.configs.base import SSMConfig


class SSMState(NamedTuple):
    """Rolling conv inputs are kept as three separate streams so each can
    carry its own PartitionSpec (x: heads sharded over 'model'; B/C:
    replicated) — a mixed-sharding concat would force resharding."""
    conv_x: jax.Array  # [B, d_conv-1, d_in]
    conv_B: jax.Array  # [B, d_conv-1, N]
    conv_C: jax.Array  # [B, d_conv-1, N]
    ssd: jax.Array     # [B, nh, hd, N] recurrent state (float32)


def init_mamba2(key, d_model: int, ssm: SSMConfig, dtype):
    d_in = ssm.expand * d_model
    nh = ssm.num_heads(d_model)
    N = ssm.d_state
    kz, kx, kb, kc, kdt, kcx, kcb, kcc, ko, ka = split_keys(key, 10)
    return {
        "w_z": dense_init(kz, d_model, d_in, dtype),
        "w_x": dense_init(kx, d_model, d_in, dtype),
        "w_B": dense_init(kb, d_model, N, dtype),
        "w_C": dense_init(kc, d_model, N, dtype),
        "w_dt": dense_init(kdt, d_model, nh, dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "conv_x_w": (jax.random.normal(kcx, (ssm.d_conv, d_in)) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_B_w": (jax.random.normal(kcb, (ssm.d_conv, N)) * 0.1).astype(dtype),
        "conv_B_b": jnp.zeros((N,), dtype),
        "conv_C_w": (jax.random.normal(kcc, (ssm.d_conv, N)) * 0.1).astype(dtype),
        "conv_C_b": jnp.zeros((N,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ka, (nh,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "w_out": dense_init(ko, d_in, d_model, dtype),
    }


def init_ssm_state(batch: int, d_model: int, ssm: SSMConfig, dtype) -> SSMState:
    d_in = ssm.expand * d_model
    nh = ssm.num_heads(d_model)
    return SSMState(
        conv_x=jnp.zeros((batch, ssm.d_conv - 1, d_in), dtype),
        conv_B=jnp.zeros((batch, ssm.d_conv - 1, ssm.d_state), dtype),
        conv_C=jnp.zeros((batch, ssm.d_conv - 1, ssm.d_state), dtype),
        ssd=jnp.zeros((batch, nh, ssm.head_dim, ssm.d_state), jnp.float32),
    )


def _causal_conv(seq, conv_state, w, b, valid=None):
    """seq: [B, S, ch]; conv_state: [B, d_conv-1, ch] (history).

    ``valid`` [B]: number of real tokens per row (the rest of ``seq``
    is executable-shape padding).  The carried-out state must then be
    the last ``d_conv-1`` inputs *at the valid frontier* — taking the
    tail of the padded sequence would seed the next chunk's conv with
    pad garbage."""
    d_conv = w.shape[0]
    full = jnp.concatenate([conv_state, seq], axis=1)
    if valid is None:
        new_state = full[:, full.shape[1] - (d_conv - 1):, :]
    else:
        # valid inputs occupy full[:, d_conv-1 : d_conv-1+valid); the
        # last d_conv-1 of them sit at [valid, valid + d_conv - 1)
        idx = valid[:, None] + jnp.arange(d_conv - 1, dtype=jnp.int32)
        new_state = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    # depthwise causal conv: y_t = sum_j w_j * x_{t-d_conv+1+j}
    S = seq.shape[1]
    out = sum(
        full[:, j: j + S, :] * w[j][None, None, :] for j in range(d_conv)
    ) + b[None, None, :]
    return jax.nn.silu(out), new_state


def _split_proj(params, u):
    """u: [B, S, d_model] -> z, x, Bm, Cm, dt (pre-conv, pre-activation)."""
    z = u @ params["w_z"]
    x = u @ params["w_x"]
    Bm = u @ params["w_B"]
    Cm = u @ params["w_C"]
    dt = (u @ params["w_dt"]).astype(jnp.float32)
    return z, x, Bm, Cm, dt


def apply_mamba2_scan(
    params, u, state: SSMState, ssm: SSMConfig, valid=None,
) -> Tuple[jax.Array, SSMState]:
    """Chunked SSD over a sequence. u: [B, S, d_model] -> (y, new_state).

    ``valid`` [B]: real tokens per row when ``u`` carries trailing
    executable-shape padding (serving chunks are padded to warmed
    shapes).  Padded positions must be state-identity: their
    post-softplus ``dt`` is zeroed (a = exp(0·A) = 1, zero injection —
    the same trick the internal chunk-size padding below already uses)
    and the conv streams carry out the frontier window, so the carried
    state is exactly the unpadded computation's.  Without this, pad
    garbage advances the recurrent state and a session's tokens depend
    on which executable shape its chunks were padded to."""
    B_, S, d_model = u.shape
    d_in = ssm.expand * d_model
    nh, hd, N = ssm.num_heads(d_model), ssm.head_dim, ssm.d_state
    Q = min(ssm.chunk_size, max(S, 1))

    z, x, Bm, Cm, dt = _split_proj(params, u)
    x, new_cx = _causal_conv(x, state.conv_x, params["conv_x_w"],
                             params["conv_x_b"], valid=valid)
    Bm, new_cb = _causal_conv(Bm, state.conv_B, params["conv_B_w"],
                              params["conv_B_b"], valid=valid)
    Cm, new_cc = _causal_conv(Cm, state.conv_C, params["conv_C_w"],
                              params["conv_C_b"], valid=valid)

    dt = jax.nn.softplus(dt + params["dt_bias"][None, None, :])  # [B,S,nh]
    if valid is not None:
        pos = jnp.arange(S, dtype=jnp.int32)[None, :, None]
        dt = jnp.where(pos < valid[:, None, None], dt, 0.0)
    A = -jnp.exp(params["A_log"])                                # [nh]
    xh = x.reshape(B_, S, nh, hd).astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    # pad S to a multiple of Q
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    def chunk(xs, H_in):
        xq, Bq, Cq, dtq = xs        # [B,Q,nh,hd], [B,Q,N], [B,Q,N], [B,Q,nh]
        dtA = dtq * A[None, None, :]                      # [B,Q,nh]
        s = jnp.cumsum(dtA, axis=1)                       # [B,Q,nh]
        # intra-chunk (diagonal) term
        dots = jnp.einsum("bin,bjn->bij", Cq, Bq)         # [B,Q,Q]
        ii = jnp.arange(Q)
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        # mask BEFORE exp: for j > i the exponent is a positive sum and
        # exp overflows — where(c, exp(x), 0) still differentiates the
        # exp branch and poisons the gradients with inf * 0 = NaN
        diff = jnp.where(causal, s[:, :, None, :] - s[:, None, :, :], -jnp.inf)
        decay = jnp.exp(diff)                             # [B,Q,Q,nh]
        M = dots[..., None] * decay * dtq[:, None, :, :]
        y = jnp.einsum("bijh,bjhd->bihd", M, xq)
        # contribution of carried-in state
        y += jnp.einsum("bin,bhdn,bih->bihd",
                        Cq, H_in, jnp.exp(s))
        # end-of-chunk state
        w = jnp.exp(s[:, -1:, :] - s) * dtq               # [B,Q,nh]
        H_intra = jnp.einsum("bjh,bjhd,bjn->bhdn", w, xq, Bq)
        H_out = H_in * jnp.exp(s[:, -1, :])[:, :, None, None] + H_intra
        return y, H_out

    xc = xh.reshape(B_, nc, Q, nh, hd).swapaxes(0, 1)
    Bc = Bm.reshape(B_, nc, Q, N).swapaxes(0, 1)
    Cc = Cm.reshape(B_, nc, Q, N).swapaxes(0, 1)
    dtc = dt.reshape(B_, nc, Q, nh).swapaxes(0, 1)

    @jax.checkpoint
    def body(H, xs):
        # rematted: the [B, Q, Q, nh] intra-chunk decay/score tensors are
        # recomputed in the backward pass instead of being saved per chunk
        y, H_new = chunk(xs, H)
        return H_new, y

    H_final, ys = jax.lax.scan(body, state.ssd, (xc, Bc, Cc, dtc))
    y = ys.swapaxes(0, 1).reshape(B_, Sp, nh, hd)[:, :S]
    y = y + xh[:, :S] * params["D"][None, None, :, None]
    y = y.reshape(B_, S, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["w_out"], SSMState(conv_x=new_cx, conv_B=new_cb,
                                         conv_C=new_cc, ssd=H_final)


def apply_mamba2_step(
    params, u, state: SSMState, ssm: SSMConfig,
) -> Tuple[jax.Array, SSMState]:
    """Single decode step. u: [B, 1, d_model] -> (y [B,1,d_model], state)."""
    B_, _, d_model = u.shape
    d_in = ssm.expand * d_model
    nh, hd, N = ssm.num_heads(d_model), ssm.head_dim, ssm.d_state

    z, x, Bm, Cm, dt = _split_proj(params, u)
    x, new_cx = _causal_conv(x, state.conv_x, params["conv_x_w"],
                             params["conv_x_b"])
    Bm, new_cb = _causal_conv(Bm, state.conv_B, params["conv_B_w"],
                              params["conv_B_b"])
    Cm, new_cc = _causal_conv(Cm, state.conv_C, params["conv_C_w"],
                              params["conv_C_b"])
    x, Bm, Cm = x[:, 0], Bm[:, 0], Cm[:, 0]

    dt = jax.nn.softplus(dt[:, 0] + params["dt_bias"][None, :])  # [B,nh]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :])                                 # [B,nh]
    xh = x.reshape(B_, nh, hd).astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    H = state.ssd * a[:, :, None, None] + jnp.einsum(
        "bh,bhd,bn->bhdn", dt, xh, Bf)
    y = jnp.einsum("bhdn,bn->bhd", H, Cm.astype(jnp.float32))
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(B_, 1, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["w_out"], SSMState(conv_x=new_cx, conv_B=new_cb,
                                         conv_C=new_cc, ssd=H)
