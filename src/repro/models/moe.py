"""Mixture-of-Experts layer (Mixtral / OLMoE / Jamba).

Two execution paths, selected by ``mode``:

* ``"gmm"``   — sort + capacity-bounded scatter into per-expert rows,
                grouped matmul ``[E, C, d] @ [E, d, f]``, gather back.
                Compute is proportional to *active* experts (top-k), which
                is what the roofline MODEL_FLOPS ratio expects.  Default
                for dry-run / production lowering.
* ``"dense"`` — every expert computes every token, outputs combined with
                the (zeroed outside top-k) router weights.  O(E) compute
                but trivially correct and shard-friendly; used as the
                oracle in tests and for tiny smoke configs.

Router: softmax over expert logits, top-k, weights renormalised over the
selected experts (Mixtral convention).  The Switch-style load-balance
auxiliary loss is returned for the training path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.configs.base import MoEConfig


def init_moe(key, d_model: int, d_ff: int, moe: MoEConfig, act: str, dtype):
    kr, kg, ku, kd = split_keys(key, 4)
    E = moe.num_experts
    params = {
        "router": dense_init(kr, d_model, E, dtype),
        "w_up": jnp.stack([dense_init(k, d_model, d_ff, dtype)
                           for k in split_keys(ku, E)]),
        "w_down": jnp.stack([dense_init(k, d_ff, d_model, dtype)
                             for k in split_keys(kd, E)]),
    }
    if act == "swiglu":
        params["w_gate"] = jnp.stack([dense_init(k, d_model, d_ff, dtype)
                                      for k in split_keys(kg, E)])
    return params


def _expert_ffn(params, h, act: str = "swiglu"):
    """h: [E, C, d] -> [E, C, d] through each expert's FFN."""
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    if act == "swiglu":
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, params["w_gate"]))
        mid = gate * up
    else:
        mid = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", mid, params["w_down"])


def _router(params, x2d, moe: MoEConfig):
    logits = (x2d @ params["router"]).astype(jnp.float32)      # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, moe.top_k)             # [T, k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return probs, weights, idx


def _aux_loss(probs, idx, moe: MoEConfig):
    """Switch-transformer load-balance loss: E * sum_e f_e * P_e."""
    E = moe.num_experts
    hits = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1)    # [T, E]
    f = hits.mean(0) / moe.top_k
    P = probs.mean(0)
    return E * jnp.sum(f * P)


def _gmm_dispatch_one(params, x2d, weights, idx, *, moe: MoEConfig,
                      act: str, C: int, expert_ffn=None):
    """Capacity-bounded sort/scatter grouped matmul for ONE token shard.
    x2d: [T, d]; weights/idx: [T, k].  Kept shard-local (vmapped over the
    data-sharded leading axis by the caller) so the sort and scatter
    never leave the device — the global variant would force XLA SPMD to
    all-gather the full token array."""
    T, d = x2d.shape
    k, E = moe.top_k, moe.num_experts
    e_flat = idx.reshape(-1)                                    # [T*k]
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = weights.reshape(-1)

    order = jnp.argsort(e_flat)                                 # stable
    se, st, sw = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=E)                     # [E]
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    dropped = pos_in_e >= C
    slot = jnp.where(dropped, E * C, se * C + pos_in_e)         # overflow row

    buf = jnp.zeros((E * C + 1, d), x2d.dtype).at[slot].set(x2d[st])
    ffn = expert_ffn if expert_ffn is not None else functools.partial(
        _expert_ffn, params, act=act)
    h = ffn(buf[: E * C].reshape(E, C, d))
    h = h.reshape(E * C, d)
    contrib = jnp.where(
        dropped[:, None], 0.0, h[jnp.where(dropped, 0, slot)] * sw[:, None]
    ).astype(x2d.dtype)
    return jnp.zeros((T, d), x2d.dtype).at[st].add(contrib)


def apply_moe(params, x, moe: MoEConfig, act: str, *,
              mode: str = "gmm", capacity_factor: float = 1.25,
              data_shards: int = 1) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    ``data_shards``: number of data-parallel shards of the token stream;
    the gmm dispatch runs independently per shard (local sort/scatter,
    per-shard capacity) — the production lowering path."""
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    probs, weights, idx = _router(params, x2d, moe)
    aux = _aux_loss(probs, idx, moe)
    k, E = moe.top_k, moe.num_experts

    if mode == "dense":
        # combine weights over all experts, zero outside top-k
        comb = jnp.zeros((T, E), jnp.float32).at[
            jnp.arange(T)[:, None], idx].set(weights)
        outs = _expert_ffn(params, jnp.broadcast_to(x2d, (E, T, d)), act)
        out = jnp.einsum("te,etd->td", comb.astype(x.dtype), outs)
        return out.reshape(B, S, d), aux

    # ---- gmm path ------------------------------------------------------
    from repro.distributed.context import current_spmd
    spmd = current_spmd()
    if spmd is not None and T % spmd.dp_size == 0:
        out = _gmm_shard_map(params, x2d, weights, idx, moe=moe, act=act,
                             capacity_factor=capacity_factor, spmd=spmd)
        return out.reshape(B, S, d), aux

    R = data_shards if T % data_shards == 0 else 1
    T_loc = T // R
    C = int(max(1, -(-T_loc * k // E) * capacity_factor))
    disp = functools.partial(_gmm_dispatch_one, params, moe=moe, act=act,
                             C=C)
    out = jax.vmap(disp)(x2d.reshape(R, T_loc, d),
                         weights.reshape(R, T_loc, k),
                         idx.reshape(R, T_loc, k))
    return out.reshape(B, S, d), aux


def _gmm_shard_map(params, x2d, weights, idx, *, moe: MoEConfig, act: str,
                   capacity_factor: float, spmd):
    """Mesh-aware gmm dispatch: ``shard_map`` runs the sort/scatter
    per-device on the token-parallel axes (XLA SPMD would otherwise
    replicate the whole token stream to partition the sort), with the
    expert FFN tensor-parallel over ``tp_axis`` (d_ff sharded; one psum
    reduces the down-projection partials, same collective as a dense TP
    MLP)."""
    from jax.sharding import PartitionSpec as P

    T, d = x2d.shape
    k, E = moe.top_k, moe.num_experts
    T_loc = T // spmd.dp_size
    # process the local tokens in bounded chunks: the dispatch buffer is
    # [E*C, d] with C ~ chunk*k/E — chunking caps the transient at a few
    # hundred MB regardless of sequence length (FLOPs unchanged).
    chunk = T_loc
    for cand in (8192, 4096, 2048, 1024):
        if T_loc % cand == 0:
            chunk = cand
            break
    n_chunks = T_loc // chunk
    C = int(max(1, -(-chunk * k // E) * capacity_factor))
    dp, tp = spmd.dp_axes, spmd.tp_axis

    has_gate = "w_gate" in params
    ffn_params = {"w_up": params["w_up"], "w_down": params["w_down"]}
    if has_gate:
        ffn_params["w_gate"] = params["w_gate"]
    # fsdp: keep the expert weights' d dim sharded over dp INSIDE the
    # shard_map and gather one expert at a time (rematted) — gathering the
    # whole [E, d, f] stack at once leaves E x 3 full-size f32 weight
    # gradients live simultaneously in the backward (measured 91 GB/device
    # for Jamba train_4k).
    fsdp = spmd.fsdp
    dspec = dp if fsdp else None
    ffn_specs = {"w_up": P(None, dspec, tp), "w_down": P(None, tp, dspec)}
    if has_gate:
        ffn_specs["w_gate"] = P(None, dspec, tp)

    def local(p_local, x_l, w_l, i_l):
        if fsdp:
            def gather(w, axis):
                return jax.lax.all_gather(w, dp, axis=axis, tiled=True)

            def expert_ffn(h):          # h: [E, C, d] -> [E, C, d]
                @jax.checkpoint
                def one_e(args):
                    he = args[0]
                    wu = gather(args[1], 0)          # [d, f_loc]
                    wd = gather(args[2], 1)          # [f_loc, d]
                    up = he @ wu
                    if has_gate:
                        gate = jax.nn.silu(he @ gather(args[3], 0))
                        mid = gate * up
                    else:
                        mid = jax.nn.gelu(up)
                    return mid @ wd
                args = (h, p_local["w_up"], p_local["w_down"])
                if has_gate:
                    args = args + (p_local["w_gate"],)
                return jax.lax.map(one_e, args)
        else:
            expert_ffn = functools.partial(_expert_ffn, p_local, act=act)

        @jax.checkpoint
        def one(args):
            # rematted: the [E*C, d] dispatch buffers are recomputed in the
            # backward pass instead of being saved per chunk
            xc, wc, ic = args
            return _gmm_dispatch_one(p_local, xc, wc, ic, moe=moe, act=act,
                                     C=C, expert_ffn=expert_ffn)
        if n_chunks > 1:
            out = jax.lax.map(one, (x_l.reshape(n_chunks, chunk, d),
                                    w_l.reshape(n_chunks, chunk, k),
                                    i_l.reshape(n_chunks, chunk, k)))
            out = out.reshape(T_loc, d)
        else:
            out = one((x_l, w_l, i_l))
        return jax.lax.psum(out, tp)

    from repro.distributed.context import shard_map
    fn = shard_map(local, mesh=spmd.mesh,
                   in_specs=(ffn_specs, P(dp, None), P(dp, None),
                             P(dp, None)),
                   out_specs=P(dp, None))
    return fn(ffn_params, x2d, weights, idx)
