"""Sanctioned modality-frontend stubs (contract carve-out).

[audio] (HuBERT) and [vlm] (Qwen2-VL) entries specify the transformer
backbone only; the mel-spectrogram conv feature extractor / ViT vision
tower are NOT implemented.  Instead these helpers produce the
*precomputed frame/patch embeddings* of the right shape that the real
frontends would emit, so the backbone, scheduler and dry-run exercise
exactly the tensor interface they would see in production.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.rope import text_positions3


def audio_frame_embeddings(cfg: ModelConfig, batch: int, frames: int,
                           key=None, dtype=jnp.float32):
    """Stand-in for the wav2vec2/HuBERT conv feature extractor output.

    Real pipeline: 16 kHz waveform -> 7-layer conv stack -> 20 ms frames
    of dim d_model. Here: unit-variance random frames."""
    if key is None:
        return jax.ShapeDtypeStruct((batch, frames, cfg.d_model), dtype)
    return jax.random.normal(key, (batch, frames, cfg.d_model), dtype)


def vision_patch_embeddings(cfg: ModelConfig, batch: int, patches: int,
                            key=None, dtype=jnp.float32):
    """Stand-in for the Qwen2-VL ViT tower + projector output."""
    if key is None:
        return jax.ShapeDtypeStruct((batch, patches, cfg.d_model), dtype)
    return jax.random.normal(key, (batch, patches, cfg.d_model), dtype)


def mrope_positions_for_image(batch: int, grid_t: int, grid_h: int,
                              grid_w: int):
    """M-RoPE (t, h, w) position triplets for a vision patch grid, matching
    the Qwen2-VL convention (temporal/height/width components)."""
    t = jnp.repeat(jnp.arange(grid_t), grid_h * grid_w)
    h = jnp.tile(jnp.repeat(jnp.arange(grid_h), grid_w), grid_t)
    w = jnp.tile(jnp.arange(grid_w), grid_t * grid_h)
    pos = jnp.stack([t, h, w], axis=-1).astype(jnp.int32)  # [S, 3]
    return jnp.broadcast_to(pos, (batch,) + pos.shape)


def mixed_vlm_positions(batch: int, n_text_prefix: int, grid, n_text_suffix: int):
    """Positions for [text prefix | image patches | text suffix] as in
    Qwen2-VL: text uses degenerate triplets, image uses the 3-D grid, and
    text after the image resumes from max(image positions) + 1."""
    gt, gh, gw = grid
    pre = text_positions3(jnp.broadcast_to(
        jnp.arange(n_text_prefix, dtype=jnp.int32), (batch, n_text_prefix)))
    img = mrope_positions_for_image(batch, gt, gh, gw) + n_text_prefix
    start = n_text_prefix + max(gt, gh, gw)
    suf = text_positions3(jnp.broadcast_to(
        start + jnp.arange(n_text_suffix, dtype=jnp.int32),
        (batch, n_text_suffix)))
    return jnp.concatenate([pre, img, suf], axis=1)
