"""Layer init/apply shared by every architecture family.

A *layer slot* is described statically by ``LayerSpec`` (attention vs
SSM mixer; dense MLP vs MoE vs none).  ``repro.models.model`` stacks
identical slot structures across repeating groups and scans over them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2
from repro.models.attention import (
    bidirectional_attention, blocked_attention, decode_attention,
    decode_attention_paged, decode_attention_seqpar, prefill_attention,
    prefill_attention_paged, prefill_attention_paged_quant,
    prefill_attention_quant, quantize_kv)
from repro.models.common import dense_init, rms_norm, split_keys
from repro.models.mlp import apply_mlp, init_mlp
from repro.models.moe import apply_moe, init_moe
from repro.models.rope import apply_mrope, apply_rope, text_positions3


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str        # "attn" | "ssm"
    ffn: str         # "mlp" | "moe" | "none"


def layer_specs_for_group(cfg: ModelConfig, group_size: int):
    """Static layout of one repeating group (layer i uses i % group_size)."""
    specs = []
    for j in range(group_size):
        kind = cfg.layer_kind(j)
        if cfg.d_ff == 0:
            ffn = "none"
        elif cfg.layer_has_moe(j):
            ffn = "moe"
        else:
            ffn = "mlp"
        specs.append(LayerSpec(kind=kind, ffn=ffn))
    return tuple(specs)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> Dict[str, Any]:
    kmix, kffn = split_keys(key, 2)
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if spec.kind == "attn":
        kq, kk, kv, ko = split_keys(kmix, 4)
        hd = cfg.head_dim
        p["attn"] = {
            "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd, dtype),
            "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dtype),
            "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dtype),
            "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model, dtype),
        }
    else:
        p["ssm"] = mamba2.init_mamba2(kmix, cfg.d_model, cfg.ssm, dtype)
    if spec.ffn != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        if spec.ffn == "moe":
            p["ffn"] = init_moe(kffn, cfg.d_model, cfg.d_ff, cfg.moe,
                                cfg.act, dtype)
        else:
            p["ffn"] = init_mlp(kffn, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _qkv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def _rope(cfg: ModelConfig, q, k, positions):
    if cfg.encoder_only:
        return q, k  # positional info comes from the (stub) conv frontend
    if cfg.mrope:
        pos3 = positions if positions.ndim == 3 else text_positions3(positions)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos = positions if positions.ndim == 2 else positions[..., 0]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def _write_kv(cache_k, cache_v, k_new, v_new, offsets):
    """Write per-batch chunks at per-batch offsets.

    cache_*: [B, S_max, Hk, hd]; k_new: [B, S, Hk, hd]; offsets: [B]."""
    def upd(c, x, o):
        return jax.lax.dynamic_update_slice_in_dim(c, x, o, axis=0)
    return (jax.vmap(upd)(cache_k, k_new, offsets),
            jax.vmap(upd)(cache_v, v_new, offsets))


def _paged_write(arena, rows, block_tables, positions):
    """Scatter new rows into the flat page arena through a block table.

    arena: [P_phys, page, Hk, x]; rows: [B, S, Hk, x]; block_tables:
    [B, P_max] physical page ids (unallocated entries already point at
    the scratch page); positions: [B, S] absolute token positions.
    Negative or beyond-table positions redirect to the scratch (last
    physical) page, which is never read — the paged analogue of the
    slab scratch row (DESIGN.md §3/§8).  Distinct sessions own distinct
    pages, so in-range scatter indices never collide."""
    P, ps = arena.shape[0], arena.shape[1]
    p_max = block_tables.shape[1]
    pos = jnp.maximum(positions, 0)
    logical = pos // ps
    page = jnp.take_along_axis(block_tables,
                               jnp.minimum(logical, p_max - 1), axis=1)
    oob = (positions < 0) | (logical >= p_max)
    page = jnp.where(oob, P - 1, page)
    flat = page * ps + pos % ps                          # [B, S]
    flat_arena = arena.reshape((P * ps,) + arena.shape[2:])
    flat_arena = flat_arena.at[flat.reshape(-1)].set(
        rows.reshape((-1,) + rows.shape[2:]))
    return flat_arena.reshape(arena.shape)


def _paged_write_quant(layer_cache, k_new, v_new, block_tables, positions):
    """Quantise new K/V tokens and scatter values + scales through the
    block table (int8 paged arena)."""
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    return {
        "k": _paged_write(layer_cache["k"], kq, block_tables, positions),
        "v": _paged_write(layer_cache["v"], vq, block_tables, positions),
        "ks": _paged_write(layer_cache["ks"],
                           ks.astype(layer_cache["ks"].dtype),
                           block_tables, positions),
        "vs": _paged_write(layer_cache["vs"],
                           vs.astype(layer_cache["vs"].dtype),
                           block_tables, positions),
    }


def _write_kv_quant(layer_cache, k_new, v_new, offsets):
    """Quantise new K/V tokens and write values + scales (int8 cache)."""
    def upd(c, x, o):
        return jax.lax.dynamic_update_slice_in_dim(c, x, o, axis=0)
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    return {
        "k": jax.vmap(upd)(layer_cache["k"], kq, offsets),
        "v": jax.vmap(upd)(layer_cache["v"], vq, offsets),
        "ks": jax.vmap(upd)(layer_cache["ks"],
                            ks.astype(layer_cache["ks"].dtype), offsets),
        "vs": jax.vmap(upd)(layer_cache["vs"],
                            vs.astype(layer_cache["vs"].dtype), offsets),
    }


def apply_attn_mixer(
    p, x, cfg: ModelConfig, *, mode: str, positions, lengths,
    layer_cache: Optional[Dict[str, jax.Array]], window: int,
    block_size: int = 512, seq_parallel=None, block_tables=None,
    write_positions=None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: [B, S, d].  ``lengths`` [B]: valid tokens in cache *before* this
    call (0 for cold prefill / train).  ``block_tables`` [B, P_max]
    switches the cache to the paged layout (leaves are page arenas);
    ``write_positions`` [B] (decode only) decouples the K/V write
    position from the attention valid-length — negative means the
    scratch page/row.  Returns (out, new_layer_cache)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if block_tables is not None:
        assert seq_parallel is None, "paged KV + seq-parallel unsupported"

    if mode == "encode":
        q, k = _rope(cfg, q, k, positions)
        out = bidirectional_attention(q, k, v, lengths=None,
                                      block_size=block_size)
    elif mode == "train":
        q, k = _rope(cfg, q, k, positions)
        out = blocked_attention(q, k, v, causal=True, window=window,
                                block_size=block_size)
    elif mode == "prefill" and block_tables is not None \
            and layer_cache is not None:
        # paged layout: chunk rows scatter into the page arena through
        # the block table; attention reads the arena via the same table
        # (gather for the XLA reference, index maps for Pallas).
        q, k = _rope(cfg, q, k, positions)
        pos_w = lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        if "ks" in layer_cache:
            layer_cache = _paged_write_quant(layer_cache, k, v,
                                             block_tables, pos_w)
            out = prefill_attention_paged_quant(
                q, layer_cache["k"], layer_cache["ks"],
                layer_cache["v"], layer_cache["vs"], block_tables,
                q_offset=lengths, lengths=lengths + S, window=window,
                block_size=block_size, backend=cfg.prefill_kernel)
        else:
            ck = _paged_write(layer_cache["k"], k, block_tables, pos_w)
            cv = _paged_write(layer_cache["v"], v, block_tables, pos_w)
            layer_cache = {"k": ck, "v": cv}
            out = prefill_attention_paged(
                q, ck, cv, block_tables, q_offset=lengths,
                lengths=lengths + S, window=window, block_size=block_size,
                backend=cfg.prefill_kernel)
    elif mode == "prefill":
        q, k = _rope(cfg, q, k, positions)
        if layer_cache is not None and "ks" in layer_cache:
            layer_cache = _write_kv_quant(layer_cache, k, v, lengths)
            out = prefill_attention_quant(
                q, layer_cache["k"], layer_cache["ks"],
                layer_cache["v"], layer_cache["vs"],
                q_offset=lengths, lengths=lengths + S,
                window=window, block_size=block_size,
                backend=cfg.prefill_kernel)
        elif layer_cache is not None:
            ck, cv = _write_kv(layer_cache["k"], layer_cache["v"],
                               k, v, lengths)
            layer_cache = {"k": ck, "v": cv}
            out = prefill_attention(
                q, ck, cv, q_offset=lengths, lengths=lengths + S,
                window=window, block_size=block_size,
                backend=cfg.prefill_kernel)
        else:  # cold prefill without a persistent cache (train-like)
            out = blocked_attention(q, k, v, causal=True, window=window,
                                    block_size=block_size)
    elif mode == "decode" and block_tables is not None:
        assert layer_cache is not None and S == 1
        q, k = _rope(cfg, q, k, positions)
        wpos = lengths if write_positions is None else write_positions
        pos_w = wpos[:, None]
        if "ks" in layer_cache:
            layer_cache = _paged_write_quant(layer_cache, k, v,
                                             block_tables, pos_w)
            out = decode_attention_paged(
                q, layer_cache["k"], layer_cache["v"], block_tables,
                lengths + 1, window=window, block_size=block_size,
                k_scale=layer_cache["ks"], v_scale=layer_cache["vs"],
                backend=cfg.decode_kernel)
        else:
            ck = _paged_write(layer_cache["k"], k, block_tables, pos_w)
            cv = _paged_write(layer_cache["v"], v, block_tables, pos_w)
            layer_cache = {"k": ck, "v": cv}
            out = decode_attention_paged(
                q, ck, cv, block_tables, lengths + 1, window=window,
                block_size=block_size, backend=cfg.decode_kernel)
    elif mode == "decode":
        assert layer_cache is not None and S == 1
        q, k = _rope(cfg, q, k, positions)
        quantized = "ks" in layer_cache
        if seq_parallel is not None:
            # shard-local write happens INSIDE the seq-parallel kernel
            if quantized:
                kq, ksn = quantize_kv(k)
                vq, vsn = quantize_kv(v)
                out, ck, cv, kss, vss = decode_attention_seqpar(
                    q, kq, vq, layer_cache["k"], layer_cache["v"],
                    lengths + 1, seq_parallel, window=window,
                    k_scale=layer_cache["ks"], v_scale=layer_cache["vs"],
                    new_scales=(ksn.astype(layer_cache["ks"].dtype),
                                vsn.astype(layer_cache["vs"].dtype)))
                layer_cache = {"k": ck, "v": cv, "ks": kss, "vs": vss}
            else:
                out, ck, cv = decode_attention_seqpar(
                    q, k, v, layer_cache["k"], layer_cache["v"],
                    lengths + 1, seq_parallel, window=window)
                layer_cache = {"k": ck, "v": cv}
        else:
            # write position decoupled from attention valid-length
            # (DESIGN.md §3): the fused path redirects inactive lanes'
            # writes to the scratch row while their attention extent
            # stays O(real length)
            wpos = lengths if write_positions is None else write_positions
            if quantized:
                layer_cache = _write_kv_quant(layer_cache, k, v, wpos)
                ck, cv = layer_cache["k"], layer_cache["v"]
                scales = dict(k_scale=layer_cache["ks"],
                              v_scale=layer_cache["vs"])
            else:
                ck, cv = _write_kv(layer_cache["k"], layer_cache["v"], k, v,
                                   wpos)
                layer_cache = {"k": ck, "v": cv}
                scales = {}
            out = decode_attention(q, ck, cv, lengths + 1, window=window,
                                   **scales)
    else:
        raise ValueError(mode)

    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"], layer_cache


def apply_layer(
    lp, x, cfg: ModelConfig, spec: LayerSpec, *, mode: str, positions,
    lengths, layer_cache, window: int, moe_mode: str, block_size: int = 512,
    moe_capacity: float = 1.25, moe_shards: int = 1, seq_parallel=None,
    block_tables=None, write_positions=None, ssm_valid=None,
):
    """Pre-norm residual block. Returns (x, new_layer_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        mixed, layer_cache = apply_attn_mixer(
            lp["attn"], h, cfg, mode=mode, positions=positions,
            lengths=lengths, layer_cache=layer_cache, window=window,
            block_size=block_size, seq_parallel=seq_parallel,
            block_tables=block_tables, write_positions=write_positions)
    else:
        state = mamba2.SSMState(**layer_cache)
        if mode == "decode":
            mixed, state = mamba2.apply_mamba2_step(lp["ssm"], h, state, cfg.ssm)
        else:
            mixed, state = mamba2.apply_mamba2_scan(lp["ssm"], h, state,
                                                    cfg.ssm, valid=ssm_valid)
        layer_cache = state._asdict()
    x = x + mixed
    if spec.ffn != "none":
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if spec.ffn == "moe":
            out, aux = apply_moe(lp["ffn"], h, cfg.moe, cfg.act,
                                 mode=moe_mode, capacity_factor=moe_capacity,
                                 data_shards=moe_shards)
        else:
            out = apply_mlp(lp["ffn"], h, cfg.act)
        x = x + out
    return x, layer_cache, aux
