"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191] splits the head_dim/2 rotary frequencies into
three sections (temporal, height, width) and rotates each section by the
corresponding position component.  For pure-text tokens all three
components are equal, which makes M-RoPE coincide with 1-D RoPE — the
property the smoke tests assert.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies, shape [head_dim//2] (float32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x, cos, sin):
    # x: [..., head_dim]; cos/sin: [..., head_dim//2]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] int32."""
    inv = rope_freqs(x.shape[-1], theta)  # [hd/2]
    ang = positions.astype(jnp.float32)[..., None] * inv  # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """M-RoPE. x: [B, S, H, hd]; positions3: [B, S, 3] (t, h, w) int32;
    sections: 3 ints summing to hd//2."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(x.shape[-1], theta)  # [half]
    # pick position component per frequency index
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, positions3.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # [B, S, half]
    ang = pos * inv
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def text_positions3(positions):
    """Expand 1-D positions to degenerate (t,h,w) triplets for text."""
    return jnp.stack([positions, positions, positions], axis=-1)
