"""Attention: blocked (flash-style) pure-JAX implementation.

This module is the XLA execution path used for training, the dry-run and
the serving engine.  It never materialises the full [Sq, Sk] score
matrix: scores are computed per KV block inside a ``lax.scan`` with an
online-softmax accumulator, so peak memory is O(Sq * block) — required
for the 32k prefill and 524k decode shapes to fit per-device HBM.

The backward pass is a hand-written ``custom_vjp`` implementing the
FlashAttention recompute algorithm: the forward saves only (q, k, v,
out, m, l) and the backward re-derives each block's probabilities.
This matters: ``lax.scan`` autodiff would otherwise checkpoint the
O(Sq x hd) accumulator carry per KV block — measured 13.7 GB/device for
one Mixtral-dims layer at train_4k, vs ~0.5 GB with this VJP.

The Pallas kernels in ``repro.kernels`` implement the same contract for
the TPU hot path; ``repro.kernels.ref`` holds the naive oracle both are
tested against.

Supports: causal masking with a per-batch query offset (resume prefill
against a cached context), per-batch valid-key lengths, sliding windows
(Mixtral SWA and the sanctioned long_500k dense variant), bidirectional
encoder attention (HuBERT), and GQA via grouped einsums (no KV head
repetition is materialised).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _pad_to_multiple(x, multiple: int, axis: int):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), size


def _block_mask(key_pos, q_pos, lengths, causal: bool, window: int):
    """valid: [B, Sq, blk] (causal) or [B, 1, blk] (padding-only)."""
    valid = key_pos[None, None, :] < lengths[:, None, None]
    if causal:
        valid = valid & (key_pos[None, None, :] <= q_pos[:, :, None])
        if window > 0:
            valid = valid & (key_pos[None, None, :] > q_pos[:, :, None] - window)
    return valid


def _flash_fwd_impl(q, k, v, q_offset, lengths, causal, window, block):
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    scale = 1.0 / (hd ** 0.5)
    nblocks = k.shape[1] // block

    qg = (q * scale).astype(jnp.float32).reshape(B, Sq, Hk, G, hd)
    q_pos = q_offset[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]

    m0 = jnp.full((B, Hk, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hk, G, Sq, hd), jnp.float32)

    kb = k.reshape(B, nblocks, block, Hk, hd).swapaxes(0, 1)
    vb = v.reshape(B, nblocks, block, Hk, hd).swapaxes(0, 1)

    def body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, blk_idx = xs
        key_pos = blk_idx * block + jnp.arange(block, dtype=jnp.int32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        valid = _block_mask(key_pos, q_pos, lengths, causal, window)
        s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kb, vb, jnp.arange(nblocks, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]   # [B, Hk, G, Sq, hd] f32
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash(causal, window, block, q, k, v, q_offset, lengths):
    out, _, _ = _flash_fwd_impl(q, k, v, q_offset, lengths, causal, window,
                                block)
    B, Sq, H, hd = q.shape
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def _flash_fwd(causal, window, block, q, k, v, q_offset, lengths):
    out, m, l = _flash_fwd_impl(q, k, v, q_offset, lengths, causal, window,
                                block)
    B, Sq, H, hd = q.shape
    o = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    return o, (q, k, v, q_offset, lengths, out, m, l)


def _flash_bwd(causal, window, block, res, do):
    q, k, v, q_offset, lengths, out, m, l = res
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    scale = 1.0 / (hd ** 0.5)
    nblocks = k.shape[1] // block

    qg = (q * scale).astype(jnp.float32).reshape(B, Sq, Hk, G, hd)
    dog = do.astype(jnp.float32).reshape(B, Sq, Hk, G, hd) \
        .transpose(0, 2, 3, 1, 4)                      # [B,Hk,G,Sq,hd]
    q_pos = q_offset[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    l_safe = jnp.maximum(l, 1e-30)
    # D_i = sum_d dO_i * O_i  (out here is already normalised)
    D = jnp.sum(dog * out, axis=-1)                    # [B,Hk,G,Sq]

    kb = k.reshape(B, nblocks, block, Hk, hd).swapaxes(0, 1)
    vb = v.reshape(B, nblocks, block, Hk, hd).swapaxes(0, 1)

    def body(dq, xs):
        k_blk, v_blk, blk_idx = xs
        key_pos = blk_idx * block + jnp.arange(block, dtype=jnp.int32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        valid = _block_mask(key_pos, q_pos, lengths, causal, window)
        s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l_safe[..., None]   # [B,Hk,G,Sq,blk]
        dv = jnp.einsum("bhgqk,bhgqd->bkhd", p, dog,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dog, v_blk.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - D[..., None])                        # [B,Hk,G,Sq,blk]
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                             k_blk.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg,
                        preferred_element_type=jnp.float32)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hk, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(nblocks, dtype=jnp.int32)))
    dq = (dq * scale).reshape(B, Sq, H, hd).astype(q.dtype)
    # dk needs no extra scale: qg in the einsum already carries 1/sqrt(hd)
    dk = dks.swapaxes(0, 1).reshape(B, -1, Hk, hd).astype(k.dtype)
    dv = dvs.swapaxes(0, 1).reshape(B, -1, Hk, hd).astype(v.dtype)
    zi = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return dq, dk, dv, zi(q_offset), zi(lengths)


_flash.defvjp(_flash_fwd, _flash_bwd)


def blocked_attention(
    q,                      # [B, Sq, H, hd]
    k,                      # [B, Sk, Hk, hd]
    v,                      # [B, Sk, Hk, hd]
    *,
    q_offset=None,          # [B] int32: absolute position of q[:, 0]
    lengths=None,           # [B] int32: number of valid keys (<= Sk)
    causal: bool = True,
    window: int = 0,        # 0 = unlimited
    block_size: int = 512,
):
    B, Sq, H, hd = q.shape
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32)
    if lengths is None:
        lengths = jnp.full((B,), k.shape[1], jnp.int32)
    block = min(block_size, k.shape[1])
    k, _ = _pad_to_multiple(k, block, 1)
    v, _ = _pad_to_multiple(v, block, 1)
    out = _flash(causal, window, block, q, k, v,
                 q_offset.astype(jnp.int32), lengths.astype(jnp.int32))
    return out


def blocked_attention_quant(
    q, k_q, k_s, v_q, v_s, *, q_offset=None, lengths=None,
    causal: bool = True, window: int = 0, block_size: int = 512,
):
    """Forward-only blocked attention over an int8-quantised KV cache.

    k_q/v_q: int8 [B, Sk, Hk, hd]; k_s/v_s: per-(position, head) scales
    [B, Sk, Hk, 1].  Dequantisation happens per KV tile inside the scan,
    so HBM traffic for the cache is halved (the §Perf memory-term
    optimization for the decode shapes); serving paths never
    differentiate through the cache, so no VJP is needed."""
    B, Sq, H, hd = q.shape
    Hk = k_q.shape[2]
    G = H // Hk
    scale = 1.0 / (hd ** 0.5)
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32)
    if lengths is None:
        lengths = jnp.full((B,), k_q.shape[1], jnp.int32)
    block = min(block_size, k_q.shape[1])
    k_q, _ = _pad_to_multiple(k_q, block, 1)
    v_q, _ = _pad_to_multiple(v_q, block, 1)
    k_s, _ = _pad_to_multiple(k_s, block, 1)
    v_s, _ = _pad_to_multiple(v_s, block, 1)
    nblocks = k_q.shape[1] // block

    qg = (q * scale).astype(jnp.float32).reshape(B, Sq, Hk, G, hd)
    q_pos = q_offset[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    m0 = jnp.full((B, Hk, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hk, G, Sq, hd), jnp.float32)

    def rb(x):
        return x.reshape(B, nblocks, block, *x.shape[2:]).swapaxes(0, 1)

    def body(carry, xs):
        m, l, acc = carry
        kq_b, ks_b, vq_b, vs_b, blk_idx = xs
        k_blk = kq_b.astype(jnp.float32) * ks_b.astype(jnp.float32)
        v_blk = vq_b.astype(jnp.float32) * vs_b.astype(jnp.float32)
        key_pos = blk_idx * block + jnp.arange(block, dtype=jnp.int32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk,
                       preferred_element_type=jnp.float32)
        valid = _block_mask(key_pos, q_pos, lengths, causal, window)
        s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (rb(k_q), rb(k_s), rb(v_q), rb(v_s),
         jnp.arange(nblocks, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def prefill_attention(q, k_cache, v_cache, *, q_offset, lengths,
                      window: int = 0, block_size: int = 512,
                      backend: str = "xla"):
    """Serving prefill/resume attention: a [B, Sq] query chunk against
    the resident KV cache [B, S_max] (chunk rows already written).

    ``backend`` selects the execution path (``ModelConfig.prefill_kernel``):
    "xla" runs the reference ``blocked_attention`` scan, which streams
    every padded cache tile; "pallas" runs the cache-aware kernel whose
    scalar-prefetched ``q_offset``/``lengths`` prune causally-dead and
    never-written KV tiles from the DMA stream (DESIGN.md §4)."""
    if backend == "pallas":
        from repro.kernels.ops import flash_prefill
        return flash_prefill(q, k_cache, v_cache, q_offset, lengths,
                             causal=True, window=window)
    return blocked_attention(q, k_cache, v_cache, q_offset=q_offset,
                             lengths=lengths, causal=True, window=window,
                             block_size=block_size)


def prefill_attention_quant(q, k_q, k_s, v_q, v_s, *, q_offset, lengths,
                            window: int = 0, block_size: int = 512,
                            backend: str = "xla"):
    """int8-KV serving prefill attention; same dispatch contract as
    ``prefill_attention`` (the Pallas path dequantises per tile in VMEM
    and applies the same tile pruning)."""
    if backend == "pallas":
        from repro.kernels.ops import flash_prefill_quant
        return flash_prefill_quant(q, k_q, k_s, v_q, v_s, q_offset, lengths,
                                   causal=True, window=window)
    return blocked_attention_quant(q, k_q, k_s, v_q, v_s, q_offset=q_offset,
                                   lengths=lengths, causal=True,
                                   window=window, block_size=block_size)


def paged_gather(arena, block_tables):
    """Linearise a page arena through block tables: arena
    [P_phys, page, Hk, x], block_tables [B, P_max] (physical page ids;
    unallocated entries already point at the scratch page) ->
    [B, P_max * page, Hk, x].  Positions >= the session's valid length
    land on scratch/stale pages — exactly like the slab layout's
    never-written rows, and masked identically by ``lengths``."""
    g = jnp.take(arena, block_tables, axis=0)
    B, pm, ps = g.shape[:3]
    return g.reshape((B, pm * ps) + g.shape[3:])


def prefill_attention_paged(q, k_arena, v_arena, block_tables, *, q_offset,
                            lengths, window: int = 0, block_size: int = 512,
                            backend: str = "xla"):
    """Paged-layout serving prefill attention (DESIGN.md §8): the
    "xla" backend gathers the session's pages into a linear view and
    runs the reference scan (bit-identical to the slab path at valid
    positions); "pallas" streams pages directly via block-table index
    maps — no gather materialised."""
    if backend == "pallas":
        from repro.kernels.ops import flash_prefill_paged
        return flash_prefill_paged(q, k_arena, v_arena, q_offset, lengths,
                                   block_tables, causal=True, window=window)
    return blocked_attention(
        q, paged_gather(k_arena, block_tables),
        paged_gather(v_arena, block_tables), q_offset=q_offset,
        lengths=lengths, causal=True, window=window, block_size=block_size)


def prefill_attention_paged_quant(q, k_arena, ks_arena, v_arena, vs_arena,
                                  block_tables, *, q_offset, lengths,
                                  window: int = 0, block_size: int = 512,
                                  backend: str = "xla"):
    """int8-KV paged prefill attention; same dispatch contract as
    ``prefill_attention_paged`` (scale leaves ride the same tables)."""
    if backend == "pallas":
        from repro.kernels.ops import flash_prefill_paged_quant
        return flash_prefill_paged_quant(
            q, k_arena, ks_arena, v_arena, vs_arena, q_offset, lengths,
            block_tables, causal=True, window=window)
    bt = block_tables
    return blocked_attention_quant(
        q, paged_gather(k_arena, bt), paged_gather(ks_arena, bt),
        paged_gather(v_arena, bt), paged_gather(vs_arena, bt),
        q_offset=q_offset, lengths=lengths, causal=True, window=window,
        block_size=block_size)


def decode_attention_paged(q, k_arena, v_arena, block_tables, lengths, *,
                           window: int = 0, block_size: int = 2048,
                           k_scale=None, v_scale=None, backend: str = "xla"):
    """Paged-layout single-token decode.  "pallas" (full-attention,
    non-quant) maps the kernel's k-tile grid index through the
    scalar-prefetched block table; otherwise pages are gathered and the
    reference ``decode_attention`` runs on the linear view (identical
    numerics — the gather is position-preserving)."""
    if backend == "pallas" and window == 0 and k_scale is None:
        from repro.kernels.ops import flash_decode_paged
        return flash_decode_paged(q, k_arena, v_arena, lengths, block_tables)
    scales = {}
    if k_scale is not None:
        scales = dict(k_scale=paged_gather(k_scale, block_tables),
                      v_scale=paged_gather(v_scale, block_tables))
    return decode_attention(
        q, paged_gather(k_arena, block_tables),
        paged_gather(v_arena, block_tables), lengths, window=window,
        block_size=block_size, **scales)


def quantize_kv(x):
    """x: [..., hd] bf16 -> (int8 values, per-(...) scale [..., 1])."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    return q.astype(jnp.int8), s.astype(x.dtype)


def decode_attention_seqpar(q, k_new, v_new, k_cache, v_cache, lengths,
                            spmd, *, window: int = 0,
                            block_size: int = 2048,
                            k_scale=None, v_scale=None,
                            new_scales=None):
    """Sequence-parallel flash decode (shard_map over the data axes),
    INCLUDING the shard-local cache write.

    The KV cache sequence dim is sharded over dp.  The new token's K/V is
    written by exactly the shard whose range covers position
    ``lengths-1`` (a local dynamic-update-slice — a global one at a
    dynamic position makes XLA gather the whole sharded cache: measured
    8.6 GB/step of all-gather for phi4-mini x long_500k, §Perf iteration
    2a, hypothesis refuted->revised).  Each device then computes flash
    stats (m, l, acc) over its local chunk and one log-sum-exp merge
    combines them:

        m* = pmax(m);  l* = psum(l e^{m-m*});  acc* = psum(acc e^{m-m*})

    Collective traffic: O(B x H x hd) once per layer.
    Returns (out, new_k_cache, new_v_cache[, new_k_scale, new_v_scale])."""
    from jax.sharding import PartitionSpec as P

    _, _, H, hd = q.shape
    S = k_cache.shape[1]
    dp = spmd.dp_axes
    ba = tuple(getattr(spmd, "batch_axes", ()) or ())
    n_shards = spmd.dp_size
    S_loc = S // n_shards
    quant = k_scale is not None

    def _local_write(cache_l, new_row, pos, offset):
        """cache_l: [B, S_loc, Hk, x]; new_row: [B, 1, Hk, x]; pos [B]."""
        local_pos = jnp.clip(pos - offset, 0, S_loc - 1)
        in_range = (pos >= offset) & (pos < offset + S_loc)

        def one(c, row, p, ok):
            upd = jax.lax.dynamic_update_slice_in_dim(c, row, p, axis=0)
            return jnp.where(ok, upd, c)
        return jax.vmap(one)(cache_l, new_row, local_pos, in_range)

    def local(q_l, kn, vn, k_l, v_l, len_l, *scales):
        # global position of this shard's first cache row
        idx = jnp.zeros((), jnp.int32)
        for i, a in enumerate(dp):
            stride = int(np.prod([spmd.mesh.shape[b] for b in dp[i + 1:]],
                                 dtype=np.int64)) if i + 1 < len(dp) else 1
            idx = idx + jax.lax.axis_index(a) * stride
        offset = idx * S_loc
        pos = len_l - 1                                      # write position
        k_l = _local_write(k_l, kn, pos, offset)
        v_l = _local_write(v_l, vn, pos, offset)
        out_scales = ()
        if quant:
            ks, vs, kns, vns = scales
            ks = _local_write(ks, kns, pos, offset)
            vs = _local_write(vs, vns, pos, offset)
            out_scales = (ks, vs)
            kf = k_l.astype(jnp.float32) * ks.astype(jnp.float32)
            vf = v_l.astype(jnp.float32) * vs.astype(jnp.float32)
        else:
            kf = k_l.astype(jnp.float32)
            vf = v_l.astype(jnp.float32)
        B_loc = q_l.shape[0]
        qg = (q_l[:, 0] * (1.0 / hd ** 0.5)).astype(jnp.float32)  # [B,H,hd]
        Hk = k_l.shape[2]
        G = H // Hk
        qg = qg.reshape(B_loc, Hk, G, hd)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kf,
                       preferred_element_type=jnp.float32)  # [B,Hk,G,S_loc]
        key_pos = offset + jnp.arange(S_loc, dtype=jnp.int32)
        valid = key_pos[None, :] < len_l[:, None]            # [B, S_loc]
        if window > 0:   # sliding window on *global* positions
            valid = valid & (key_pos[None, :] >= len_l[:, None] - window)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)                                   # [B,Hk,G]
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        acc = jnp.einsum("bhgk,bkhd->bhgd", p, vf,
                         preferred_element_type=jnp.float32)
        # LSE merge across shards (the single collective round)
        m_g = jax.lax.pmax(m, dp)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, dp)
        acc_g = jax.lax.psum(acc * corr[..., None], dp)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return (out.reshape(B_loc, 1, H, hd).astype(q_l.dtype),
                k_l, v_l) + out_scales

    b_ax = ba if ba else None
    specs_kv = P(b_ax, dp, None, None)
    specs_q = P(b_ax, None, None, None)
    in_specs = [specs_q, specs_q, specs_q, specs_kv, specs_kv, P(b_ax)]
    args = [q, k_new, v_new, k_cache, v_cache, lengths]
    out_specs = (specs_q, specs_kv, specs_kv)
    if quant:
        in_specs += [specs_kv, specs_kv, specs_q, specs_q]
        args += [k_scale, v_scale] + list(new_scales)
        out_specs = out_specs + (specs_kv, specs_kv)
    from repro.distributed.context import shard_map
    fn = shard_map(local, mesh=spmd.mesh, in_specs=tuple(in_specs),
                   out_specs=out_specs)
    return fn(*args)


def decode_attention(
    q,                      # [B, 1, H, hd]
    k_cache,                # [B, S, Hk, hd]   (int8 when quantised)
    v_cache,                # [B, S, Hk, hd]
    lengths,                # [B] int32: tokens valid in cache (incl. current)
    *,
    window: int = 0,
    block_size: int = 2048,
    k_scale=None,           # [B, S, Hk, 1] when the cache is int8
    v_scale=None,
):
    """Single-token decode against a KV cache.

    With ``window > 0`` only the last ``window`` cache entries are read
    (per-batch dynamic slice) — this is what makes long_500k decode
    sub-quadratic-in-practice for SWA architectures: compute and bytes
    are O(window), not O(S)."""
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    quant = k_scale is not None
    if window > 0 and window < S:
        starts = jnp.maximum(lengths - window, 0)  # [B]

        def slice_one(c, s):
            return jax.lax.dynamic_slice_in_dim(c, s, window, axis=0)

        sl = lambda c: jax.vmap(slice_one)(c, starts)
        # positions of sliced keys are starts + arange(window); valid while
        # < lengths.  Re-express as lengths relative to the slice.
        rel_len = lengths - starts
        if quant:
            return blocked_attention_quant(
                q, sl(k_cache), sl(k_scale), sl(v_cache), sl(v_scale),
                q_offset=rel_len - 1, lengths=rel_len, causal=True,
                window=0, block_size=min(block_size, window))
        return blocked_attention(
            q, sl(k_cache), sl(v_cache), q_offset=rel_len - 1,
            lengths=rel_len, causal=True, window=0,
            block_size=min(block_size, window),
        )
    if quant:
        return blocked_attention_quant(
            q, k_cache, k_scale, v_cache, v_scale, q_offset=lengths - 1,
            lengths=lengths, causal=True, window=0, block_size=block_size)
    return blocked_attention(
        q, k_cache, v_cache, q_offset=lengths - 1, lengths=lengths,
        causal=True, window=0, block_size=block_size,
    )


def bidirectional_attention(q, k, v, lengths=None, block_size: int = 512):
    """Encoder attention (HuBERT): full bidirectional with padding mask."""
    return blocked_attention(
        q, k, v, lengths=lengths, causal=False, block_size=block_size,
    )
